"""Continuous-batching serving engine (DESIGN.md §12).

One :class:`Engine` owns a fixed-shape decode batch of ``max_batch``
slots over a :class:`~repro.serve.kv_cache.PagedDecodeCache`.  Every tick
it (1) retires finished sequences and frees their pages, (2) admits
queued prompts into free slots — at most ``max_prefill_per_tick`` per
tick, the prefill/decode disaggregation that keeps long prefills from
stalling the in-flight batch — and (3) runs ONE compiled decode step at
the fixed ``(max_batch, 1)`` shape with active-slot masking and per-row
positions.  All jitted programs are built once in ``__init__`` (the
hoisted-jit satellite): prefill compiles once per distinct prompt
length, admit-write and decode exactly once.

At temperature 0 the per-row outputs are BIT-IDENTICAL to the static
``launch/serve.generate`` reference with the same ``max_len``
(tests/test_serving.py pins this, including mid-stream admissions): the
vector-position decode writes the same cache values, garbage rows/pages
only ever contribute exp(NEG_INF) = 0.0 to the softmax, and XLA's
per-row results are batch-size-stable.  The one documented exception is
capacity-dispatch MoE decode (tokens mix across rows); int8 KV
quantization is lossy by construction.

Timing is injectable: the default :class:`Clock` reads the wall;
:class:`SimClock` + :class:`SimCosts` run the SAME scheduling logic on
modeled per-step costs — fully deterministic, which is what the
``serving`` suite of scripts/bench_ci.py gates.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.serve.kv_cache import PagedDecodeCache


# ---------------------------------------------------------------------------
# Clocks
# ---------------------------------------------------------------------------

class Clock:
    """Wall clock with an idle fast-forward: ``skip_to`` advances a virtual
    offset instead of sleeping, so a trace with gaps replays without
    penalizing the server for having no work."""

    def __init__(self):
        self._t0 = time.perf_counter()
        self._offset = 0.0

    def now(self) -> float:
        return time.perf_counter() - self._t0 + self._offset

    def skip_to(self, t: float) -> None:
        self._offset += max(0.0, t - self.now())

    def advance(self, dt: float) -> None:   # no-op: real work takes real time
        del dt


class SimClock:
    """Virtual clock for deterministic simulation: work advances it by
    modeled costs (:class:`SimCosts`), idleness skips it forward."""

    def __init__(self):
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def skip_to(self, t: float) -> None:
        self.t = max(self.t, t)

    def advance(self, dt: float) -> None:
        self.t += dt


@dataclasses.dataclass(frozen=True)
class SimCosts:
    """Modeled per-step costs for simulated serving: a prefill charges
    ``tokens x prefill_s_per_token``; every decode tick charges the flat
    ``decode_step_s`` of the fixed-shape compiled step."""
    prefill_s_per_token: float = 2e-4
    decode_step_s: float = 2e-3


# ---------------------------------------------------------------------------
# Requests / completions
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new: int                  # generated tokens incl. the prefill token
    arrival_s: float = 0.0
    temperature: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])


@dataclasses.dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: np.ndarray            # (n,) int32 generated tokens
    arrival_s: float
    admit_s: float
    emit_s: List[float]           # per-token emission times

    @property
    def first_token_s(self) -> float:
        """First emission, or the admit time for a zero-token completion
        (``max_new=0`` requests emit nothing; the request still occupied
        the engine until admission finished)."""
        return self.emit_s[0] if self.emit_s else self.admit_s

    @property
    def finish_s(self) -> float:
        return self.emit_s[-1] if self.emit_s else self.admit_s

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def per_token_latency_s(self) -> float:
        """Normalized request latency — the serving metric the bench
        reports p50/p99 of: (finish - arrival) / generated tokens."""
        return (self.finish_s - self.arrival_s) / max(len(self.tokens), 1)


def poisson_trace(n: int, mean_interarrival_s: float, prompt_len: int,
                  max_new_choices: Sequence[int], vocab: int,
                  seed: int = 0) -> List[Request]:
    """A deterministic Poisson arrival trace: exponential interarrivals,
    random prompts, and generation lengths drawn from
    ``max_new_choices`` (a skewed mix makes the static baseline pay the
    max-length padding tax)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n):
        t += float(rng.exponential(mean_interarrival_s))
        out.append(Request(
            rid=i,
            prompt=rng.integers(0, vocab, size=(prompt_len,)).astype(np.int32),
            max_new=int(rng.choice(np.asarray(max_new_choices))),
            arrival_s=t))
    return out


def latency_summary(completions: Sequence[Completion]) -> Dict[str, float]:
    """Throughput + per-token latency percentiles over a finished trace."""
    if not completions:
        return {"tokens": 0, "tokens_per_s": 0.0, "makespan_s": 0.0,
                "p50_s": 0.0, "p99_s": 0.0, "mean_ttft_s": 0.0}
    toks = sum(len(c.tokens) for c in completions)
    t0 = min(c.arrival_s for c in completions)
    t1 = max(c.finish_s for c in completions)
    lat = np.asarray([c.per_token_latency_s for c in completions])
    return {"tokens": toks,
            "tokens_per_s": toks / max(t1 - t0, 1e-12),
            "makespan_s": t1 - t0,
            "p50_s": float(np.percentile(lat, 50)),
            "p99_s": float(np.percentile(lat, 99)),
            "mean_ttft_s": float(np.mean([c.ttft_s for c in completions]))}


# ---------------------------------------------------------------------------
# ServeConfig + Engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 64
    page_size: int = 8
    n_pages: Optional[int] = None       # default: fully provisioned + trash
    quantize: Optional[str] = None      # "int8" for lossy paged KV
    max_prefill_per_tick: int = 1
    eos_id: Optional[int] = None
    seed: int = 0


class _Slot:
    __slots__ = ("req", "pos", "last", "tokens", "admit_s", "emit_s")

    def __init__(self, req: Request, admit_s: float):
        self.req = req
        self.pos = req.prompt_len     # next cache position to write
        self.last = 0                 # last generated token (decode input)
        self.tokens: List[int] = []
        self.admit_s = admit_s
        self.emit_s: List[float] = []


class Engine:
    """One serving replica.  ``sim=SimCosts(...)`` (with a
    :class:`SimClock`) runs the identical admission/retirement state
    machine on modeled costs and synthetic tokens — no device work."""

    def __init__(self, model, params, cfg: ServeConfig, clock=None,
                 sim: Optional[SimCosts] = None, dtype=None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.sim = sim
        self.clock = clock if clock is not None else (
            SimClock() if sim is not None else Clock())
        self.cache = PagedDecodeCache(
            model, cfg.max_batch, cfg.max_len, cfg.page_size,
            n_pages=cfg.n_pages, quantize=cfg.quantize, dtype=dtype,
            build_pool=sim is None)
        self.pool = self.cache.pool
        self._slots: List[Optional[_Slot]] = [None] * cfg.max_batch
        self._pending: deque = deque()      # not yet arrived (by arrival_s)
        self._queue: deque = deque()        # arrived, waiting for admission
        self._rng_base = None if sim is not None else __import__(
            "jax").random.PRNGKey(cfg.seed)
        self.decode_ticks = 0
        self.prefills = 0
        if sim is None:
            self._build_jits()

    # -- compiled programs (built ONCE; the hoisted-jit satellite) ----------

    def _build_jits(self):
        import jax
        import jax.numpy as jnp
        from repro.models.sharding_ctx import mesh_ctx
        model, cache, max_len = self.model, self.cache, self.cfg.max_len

        # The activation-sharding context is process-global and set by the
        # TRAINING launcher; a server built in the same process must not
        # inherit it — a stale mesh would bake with_sharding_constraint ops
        # into the serving programs (committed NamedSharding outputs -> a
        # second executable-cache entry per jit, breaking the compile-once
        # contract) and change num_batch_shards() under MoE dispatch.
        def prefill_fn(params, tokens):
            with mesh_ctx(None, ()):
                return model.prefill(params, {"tokens": tokens},
                                     max_len=max_len)

        def admit_fn(pool, cache_row, table_row, slot):
            return cache.write_prefill(pool, cache_row, table_row, slot)

        def decode_fn(params, pool, tokens, pos, tables, active):
            with mesh_ctx(None, ()):
                linear = cache.gather(pool, tables)
                pos_c = jnp.where(active, pos, 0)
                logits, new_linear = model.decode_step(params, tokens,
                                                       linear, pos_c)
                pool = cache.scatter_token(pool, new_linear, pos_c, tables,
                                           active)
                last = logits[:, -1]
                return (jnp.argmax(last, axis=-1).astype(jnp.int32), last,
                        pool)

        self._prefill = jax.jit(prefill_fn)
        self._admit = jax.jit(admit_fn, donate_argnums=(0,))
        self._decode = jax.jit(decode_fn, donate_argnums=(1,))

    def compile_counts(self) -> Dict[str, int]:
        """Traced-program counts per compiled entry point (engine contract:
        decode and admit trace exactly once; prefill once per distinct
        prompt length)."""
        out = {}
        for name in ("_prefill", "_admit", "_decode"):
            fn = getattr(self, name, None)
            if fn is not None and hasattr(fn, "_cache_size"):
                out[name[1:]] = fn._cache_size()
        return out

    # -- bookkeeping --------------------------------------------------------

    def submit(self, req: Request) -> None:
        if req.max_new < 0:
            raise ValueError(f"max_new must be >= 0, got {req.max_new}")
        if req.prompt_len + req.max_new > self.cfg.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + max_new "
                f"{req.max_new} exceeds max_len {self.cfg.max_len}")
        self._pending.append(req)
        self._pending = deque(sorted(self._pending,
                                     key=lambda r: (r.arrival_s, r.rid)))

    def load(self) -> int:
        """Outstanding work (router metric): waiting + in flight."""
        return (len(self._pending) + len(self._queue)
                + sum(s is not None for s in self._slots))

    def busy(self) -> bool:
        return self.load() > 0

    def _ingest(self) -> None:
        now = self.clock.now()
        while self._pending and self._pending[0].arrival_s <= now:
            self._queue.append(self._pending.popleft())

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def _sample(self, row_logits, temperature: float, rid: int,
                step: int) -> int:
        import jax
        import jax.numpy as jnp
        if temperature <= 0.0:
            return int(np.argmax(np.asarray(row_logits)))
        key = jax.random.fold_in(jax.random.fold_in(self._rng_base, rid),
                                 step)
        return int(jax.random.categorical(
            key, jnp.asarray(row_logits) / temperature))

    def _sim_token(self, rid: int, step: int) -> int:
        return (rid * 997 + step * 31) % 1000

    # -- the tick -----------------------------------------------------------

    def _admit_one(self, req: Request, slot: int) -> List[Completion]:
        import jax.numpy as jnp
        need = req.prompt_len + req.max_new
        self.cache.alloc(slot, need)
        admit_s = self.clock.now()
        if self.sim is not None:
            self.clock.advance(req.prompt_len * self.sim.prefill_s_per_token)
            first = self._sim_token(req.rid, 0)
        else:
            logits, cache_row = self._prefill(self.params,
                                             jnp.asarray(req.prompt)[None, :])
            first = self._sample(logits[0, -1], req.temperature, req.rid, 0)
            table_row = {L: jnp.asarray(a.table()[slot])
                         for L, a in self.cache.allocators.items()}
            self.pool = self._admit(self.pool, cache_row, table_row,
                                    jnp.asarray(slot, jnp.int32))
        self.prefills += 1
        s = _Slot(req, admit_s)
        s.last = first
        if req.max_new >= 1:
            # max_new counts the prefill token; max_new=0 requests admit
            # (and pay prefill) but emit nothing
            s.tokens.append(first)
            s.emit_s.append(self.clock.now())
        self._slots[slot] = s
        return self._retire_if_done(slot)

    def _retire_if_done(self, slot: int) -> List[Completion]:
        s = self._slots[slot]
        done = (len(s.tokens) >= s.req.max_new
                or (self.cfg.eos_id is not None and s.tokens
                    and s.tokens[-1] == self.cfg.eos_id))
        if not done:
            return []
        self._slots[slot] = None
        self.cache.free(slot)
        return [Completion(rid=s.req.rid, prompt_len=s.req.prompt_len,
                           tokens=np.asarray(s.tokens, np.int32),
                           arrival_s=s.req.arrival_s, admit_s=s.admit_s,
                           emit_s=list(s.emit_s))]

    def _decode_tick(self) -> List[Completion]:
        B = self.cfg.max_batch
        active = np.array([s is not None for s in self._slots])
        if not active.any():
            return []
        tokens = np.array([[s.last if s else 0] for s in self._slots],
                          np.int32)
        pos = np.array([s.pos if s else 0 for s in self._slots], np.int32)
        self.decode_ticks += 1
        if self.sim is not None:
            self.clock.advance(self.sim.decode_step_s)
            nxt = np.array([self._sim_token(s.req.rid, len(s.tokens))
                            if s else 0 for s in self._slots])
            logits = None
        else:
            import jax.numpy as jnp
            greedy, logits, self.pool = self._decode(
                self.params, self.pool, jnp.asarray(tokens),
                jnp.asarray(pos), self.cache.tables(), jnp.asarray(active))
            nxt = np.asarray(greedy)
        now = self.clock.now()
        done: List[Completion] = []
        for b in range(B):
            s = self._slots[b]
            if s is None:
                continue
            if self.sim is not None or s.req.temperature <= 0.0:
                tok = int(nxt[b])
            else:
                tok = self._sample(logits[b], s.req.temperature, s.req.rid,
                                   len(s.tokens))
            s.pos += 1
            s.last = tok
            s.tokens.append(tok)
            s.emit_s.append(now)
            done += self._retire_if_done(b)
        return done

    def step(self) -> List[Completion]:
        """One engine tick: ingest arrivals, admit (bounded prefills),
        decode the in-flight batch, retire finished rows."""
        done: List[Completion] = []
        self._ingest()
        if (not self._queue and not any(self._slots) and self._pending):
            self.clock.skip_to(self._pending[0].arrival_s)
            self._ingest()
        admits = 0
        while self._queue and admits < self.cfg.max_prefill_per_tick:
            slot = self._free_slot()
            if slot is None:
                break
            req = self._queue[0]
            if not self.cache.can_admit(req.prompt_len + req.max_new):
                break                      # FCFS: wait for pages to free
            self._queue.popleft()
            done += self._admit_one(req, slot)
            admits += 1
        done += self._decode_tick()
        return done

    def run(self, requests: Sequence[Request]) -> List[Completion]:
        for r in requests:
            self.submit(r)
        out: List[Completion] = []
        while self.busy():
            out += self.step()
        self.cache.check()
        return sorted(out, key=lambda c: c.rid)


# ---------------------------------------------------------------------------
# Static-batching baseline
# ---------------------------------------------------------------------------

def static_compiled(model):
    """The (prefill, decode) jit pair :func:`run_static` uses — build once
    and pass via ``compiled=`` to keep compilation out of a measured run.
    Traced under a cleared activation-sharding context for the same reason
    as ``Engine._build_jits``: serving programs must not inherit a leaked
    training mesh."""
    import jax
    from repro.models.sharding_ctx import mesh_ctx

    def prefill_fn(params, batch, *, max_len):
        with mesh_ctx(None, ()):
            return model.prefill(params, batch, max_len=max_len)

    def decode_fn(params, tok, cache, pos):
        with mesh_ctx(None, ()):
            return model.decode_step(params, tok, cache, pos)

    return (jax.jit(prefill_fn, static_argnames=("max_len",)),
            jax.jit(decode_fn, donate_argnums=(2,)))


def run_static(model, params, requests: Sequence[Request], max_batch: int,
               max_len: int, clock=None, sim: Optional[SimCosts] = None,
               dtype=None, compiled=None) -> List[Completion]:
    """The static-batching baseline the bench compares against: FCFS
    batches of up to ``max_batch`` ARRIVED requests; each batch prefills
    together and decodes in lockstep to the batch's LONGEST ``max_new``
    (shorter rows pay the padding tax).  Shares the engine's clock
    semantics and, in real mode, the classic scalar-``pos`` decode graph
    compiled once at the padded ``(max_batch, 1)`` shape."""
    clock = clock if clock is not None else (
        SimClock() if sim is not None else Clock())
    todo = deque(sorted(requests, key=lambda r: (r.arrival_s, r.rid)))
    out: List[Completion] = []
    if sim is None:
        prefill, decode = (compiled if compiled is not None
                           else static_compiled(model))

    while todo:
        if todo[0].arrival_s > clock.now():
            clock.skip_to(todo[0].arrival_s)
        batch = []
        while todo and len(batch) < max_batch \
                and todo[0].arrival_s <= clock.now():
            batch.append(todo.popleft())
        P = batch[0].prompt_len
        assert all(r.prompt_len == P for r in batch), \
            "static batching pads prompts to one length per batch"
        gen = max(r.max_new for r in batch)
        admit_s = clock.now()
        rows = [r.prompt for r in batch]
        rows += [rows[-1]] * (max_batch - len(batch))   # shape padding
        toks: List[List[int]] = [[] for _ in batch]
        emit: List[List[float]] = [[] for _ in batch]

        if sim is not None:
            clock.advance(sum(r.prompt_len for r in batch)
                          * sim.prefill_s_per_token)
            for i, r in enumerate(batch):
                if r.max_new >= 1:
                    toks[i].append((r.rid * 997) % 1000)
                    emit[i].append(clock.now())
            for step in range(1, gen):
                clock.advance(sim.decode_step_s)
                now = clock.now()
                for i, r in enumerate(batch):
                    if step < r.max_new:
                        toks[i].append((r.rid * 997 + step * 31) % 1000)
                        emit[i].append(now)
        else:
            import jax.numpy as jnp
            prompts = jnp.asarray(np.stack(rows))
            logits, cache = prefill(params, {"tokens": prompts},
                                    max_len=max_len)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            host = np.asarray(tok[:, 0])
            now = clock.now()
            for i, r in enumerate(batch):
                if r.max_new >= 1:
                    toks[i].append(int(host[i]))
                    emit[i].append(now)
            for step in range(1, gen):
                logits, cache = decode(params, tok, cache,
                                       jnp.asarray(P + step - 1, jnp.int32))
                tok = jnp.argmax(logits[:, -1], axis=-1).astype(
                    jnp.int32)[:, None]
                host = np.asarray(tok[:, 0])
                now = clock.now()
                for i, r in enumerate(batch):
                    if step < r.max_new:
                        toks[i].append(int(host[i]))
                        emit[i].append(now)

        for i, r in enumerate(batch):
            out.append(Completion(rid=r.rid, prompt_len=r.prompt_len,
                                  tokens=np.asarray(toks[i], np.int32),
                                  arrival_s=r.arrival_s, admit_s=admit_s,
                                  emit_s=emit[i]))
    return sorted(out, key=lambda c: c.rid)
