"""Multi-replica sharded decode (DESIGN.md §12).

Data-parallel serving: N independent :class:`~repro.serve.engine.Engine`
replicas behind a load-aware router.  Each replica holds a full model
copy (or a TP shard group priced by
:func:`repro.core.schedule.cost.decode_step_cost_s`); requests are
routed at submit time to the least-loaded replica, ties broken
round-robin so equal replicas share work deterministically.  The
topology side — which tier the TP decode collectives land on and how
many replicas the remaining world supports — is chosen by
:func:`repro.core.schedule.planner.plan_serving`.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.serve.engine import Completion, Engine, Request


class LeastLoadedRouter:
    """Pick the replica with the fewest outstanding requests; ties break
    round-robin so a burst at t=0 still spreads across replicas."""

    def __init__(self):
        self._rr = 0

    def pick(self, loads: Sequence[int]) -> int:
        lo = min(loads)
        tied = [i for i, l in enumerate(loads) if l == lo]
        choice = tied[self._rr % len(tied)]
        self._rr += 1
        return choice


class MultiReplicaServer:
    """Route each request to a replica at submit time, then tick every
    busy replica round-robin until the trace drains."""

    def __init__(self, engines: List[Engine],
                 router: Optional[LeastLoadedRouter] = None):
        if not engines:
            raise ValueError("need at least one engine")
        self.engines = engines
        self.router = router if router is not None else LeastLoadedRouter()
        self.routes: List[int] = []     # replica index per submitted request

    def submit(self, req: Request) -> int:
        idx = self.router.pick([e.load() for e in self.engines])
        self.engines[idx].submit(req)
        self.routes.append(idx)
        return idx

    def run(self, requests: Sequence[Request]) -> List[Completion]:
        for r in sorted(requests, key=lambda r: (r.arrival_s, r.rid)):
            self.submit(r)
        out: List[Completion] = []
        while any(e.busy() for e in self.engines):
            for e in self.engines:
                if e.busy():
                    out += e.step()
        for e in self.engines:
            e.cache.check()
        return sorted(out, key=lambda c: c.rid)
