"""Production serving subsystem (DESIGN.md §12): paged KV cache,
continuous-batching engine, and topology-aware multi-replica decode."""
from repro.serve.engine import (Clock, Completion, Engine, Request,  # noqa: F401
                                ServeConfig, SimClock, SimCosts,
                                poisson_trace, run_static)
from repro.serve.kv_cache import (PageAllocator, PagedDecodeCache,  # noqa: F401
                                  TRASH_PAGE)
from repro.serve.sharded import (LeastLoadedRouter,  # noqa: F401
                                 MultiReplicaServer)
