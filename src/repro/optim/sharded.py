"""Sharded (ZeRO-style) optimizer states: init/update over partitioned flat
buckets (DESIGN.md §8).

In sharded-DP mode the optimizer runs on per-bucket SHARDS — each rank
updates only the (m,) slice of master params and moments it owns — so the
state pytrees here are lists of flat buffers, one per plan bucket, not
leaf-shaped trees.

  * ``adam`` / ``sgd`` are elementwise: the registered replicated update
    applied to shard leaves is bit-identical to the replicated update
    restricted to the shard, so they delegate straight to
    ``make_optimizer`` (this is what makes sharded mode bit-compatible
    with replicated DP for dense fp32).
  * ``lamb`` / ``lars`` are layerwise: the trust ratio needs per-LAYER
    norms, which one shard only partially sees.  Their sharded variants
    segment-sum partial squared norms per leaf (using the layout's static
    leaf-segment ids; padding slots map to a dropped sentinel segment) and
    ``psum`` the tiny (n_leaves,) vector over the data axes — one scalar
    collective per step, the standard ZeRO-LAMB construction.  They must
    run inside a shard_map whose manual axes are exactly ``axes``.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.base import Optimizer, Schedule, make_optimizer, resolve_lr

ELEMENTWISE = ("adam", "sgd")


def make_sharded_optimizer(name: str, layout, axes: Sequence[str],
                           **kwargs) -> Optimizer:
    """Optimizer over per-bucket shard lists for ``layout``.  ``axes`` are
    the manual data axes the caller's shard_map carries (used only by the
    layerwise optimizers' norm reduction)."""
    if name in ELEMENTWISE:
        return make_optimizer(name, **kwargs)
    if name == "lamb":
        return _sharded_lamb(layout, tuple(axes), **kwargs)
    if name == "lars":
        return _sharded_lars(layout, tuple(axes), **kwargs)
    raise KeyError(f"no sharded variant for optimizer {name!r}; known: "
                   f"{ELEMENTWISE + ('lamb', 'lars')}")


def _my_segments(layout, axes):
    """Per-bucket (m,) leaf-segment ids of THIS rank's shard, DERIVED from
    the static per-bucket leaf offsets — O(m) iota + a leaf-count-sized
    table per bucket.  (Embedding ``layout.seg_rows`` as an on-device
    constant would park a params-sized int32 array on EVERY device,
    defeating the 1/p memory goal sharded mode exists for; ``seg_rows``
    stays the host-side reference the tests compare against.)

    Under nested chunking the canonical chunk at mesh position (i1, i2,
    ...) covers a CONTIGUOUS flat range: global position of slot k is
    Σ_l i_l·m_l + k, and the slot is real (not padding) iff its local
    offset at every nesting level stays inside that level's parent length.
    """
    from repro.core.shard_state import nested_ms
    axes = tuple(axes)
    segs = []
    for b in layout.buckets:
        ms = nested_ms(b.n, layout.axis_sizes)
        lens = [b.n] + ms[:-1]          # parent length per nesting level
        pos = jnp.arange(ms[-1], dtype=jnp.int32)
        if axes:
            ok = jnp.ones((ms[-1],), bool)
            for ax, m, ln in zip(reversed(axes), reversed(ms),
                                 reversed(lens)):
                pos = jax.lax.axis_index(ax).astype(jnp.int32) * m + pos
                ok = ok & (pos < ln)
        else:
            ok = pos < b.n
        starts = np.cumsum([0] + list(b.sizes))[:-1].astype(np.int32)
        ids = jnp.asarray(np.asarray(b.leaves, np.int32))
        at = jnp.searchsorted(jnp.asarray(starts),
                              jnp.clip(pos, 0, b.n - 1), side="right") - 1
        segs.append(jnp.where(ok, ids[at],
                              jnp.int32(layout.n_leaves)).astype(jnp.int32))
    return segs


def _sharded_lamb(layout, axes, lr: Schedule = 1e-3, b1: float = 0.9,
                  b2: float = 0.999, eps: float = 1e-6,
                  weight_decay: float = 0.01) -> Optimizer:
    L = layout.n_leaves

    def init(shards):
        z = lambda s: jnp.zeros(s.shape, jnp.float32)
        return {"m": jax.tree.map(z, shards), "v": jax.tree.map(z, shards)}

    def update(grads, state, params, step):
        eta = resolve_lr(lr, step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        segs = _my_segments(layout, axes)
        rs, ms, vs = [], [], []
        w_sq = jnp.zeros((L,), jnp.float32)
        r_sq = jnp.zeros((L,), jnp.float32)
        for g, m, v, p, seg in zip(grads, state["m"], state["v"], params,
                                   segs):
            g = g.astype(jnp.float32)
            pf = p.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            r = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * pf
            w_sq += jax.ops.segment_sum(jnp.square(pf), seg,
                                        num_segments=L + 1)[:L]
            r_sq += jax.ops.segment_sum(jnp.square(r), seg,
                                        num_segments=L + 1)[:L]
            rs.append(r), ms.append(m), vs.append(v)
        if axes:
            w_sq = jax.lax.psum(w_sq, axes)
            r_sq = jax.lax.psum(r_sq, axes)
        w_norm, r_norm = jnp.sqrt(w_sq), jnp.sqrt(r_sq)
        trust = jnp.where((w_norm > 0) & (r_norm > 0), w_norm / r_norm, 1.0)
        trust = jnp.concatenate([trust, jnp.ones((1,), jnp.float32)])
        updates = [-eta * trust[seg] * r for seg, r in zip(segs, rs)]
        return updates, {"m": ms, "v": vs}

    return Optimizer("lamb", init, update)


def _sharded_lars(layout, axes, lr: Schedule = 1.0, momentum: float = 0.9,
                  weight_decay: float = 1e-4, trust_coef: float = 0.001,
                  eps: float = 1e-9) -> Optimizer:
    L = layout.n_leaves

    def init(shards):
        return {"mu": jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                                   shards)}

    def update(grads, state, params, step):
        eta = resolve_lr(lr, step)
        segs = _my_segments(layout, axes)
        gs = []
        w_sq = jnp.zeros((L,), jnp.float32)
        g_sq = jnp.zeros((L,), jnp.float32)
        for g, p, seg in zip(grads, params, segs):
            pf = p.astype(jnp.float32)
            g = g.astype(jnp.float32) + weight_decay * pf
            w_sq += jax.ops.segment_sum(jnp.square(pf), seg,
                                        num_segments=L + 1)[:L]
            g_sq += jax.ops.segment_sum(jnp.square(g), seg,
                                        num_segments=L + 1)[:L]
            gs.append(g)
        if axes:
            w_sq = jax.lax.psum(w_sq, axes)
            g_sq = jax.lax.psum(g_sq, axes)
        w_norm, g_norm = jnp.sqrt(w_sq), jnp.sqrt(g_sq)
        trust = jnp.where((w_norm > 0) & (g_norm > 0),
                          trust_coef * w_norm / (g_norm + eps), 1.0)
        trust = jnp.concatenate([trust, jnp.ones((1,), jnp.float32)])
        mu = [momentum * mu_j + eta * trust[seg] * g
              for mu_j, seg, g in zip(state["mu"], segs, gs)]
        return [-m for m in mu], {"mu": mu}

    return Optimizer("lars", init, update)
