"""LAMB — layerwise adaptive large-batch optimizer (survey §3.1.1; You et
al. 2020).  Adam direction with a per-layer trust ratio; fixes LARS's poor
behaviour on attention models (BERT in 76 minutes)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, Schedule, register, resolve_lr


@register("lamb")
def lamb(lr: Schedule = 1e-3, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-6, weight_decay: float = 0.01) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        eta = resolve_lr(lr, step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            pf = p.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            r = (m / c1) / (jnp.sqrt(v / c2) + eps) + weight_decay * pf
            w_norm = jnp.linalg.norm(pf)
            r_norm = jnp.linalg.norm(r)
            trust = jnp.where((w_norm > 0) & (r_norm > 0), w_norm / r_norm, 1.0)
            return -eta * trust * r, m, v

        trip = jax.tree.map(upd, grads, state["m"], state["v"], params)
        is_t = lambda x: isinstance(x, tuple)
        return (jax.tree.map(lambda x: x[0], trip, is_leaf=is_t),
                {"m": jax.tree.map(lambda x: x[1], trip, is_leaf=is_t),
                 "v": jax.tree.map(lambda x: x[2], trip, is_leaf=is_t)})

    return Optimizer("lamb", init, update)
