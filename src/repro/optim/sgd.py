"""SGD with momentum (the survey's workhorse, §2.1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, Schedule, register, resolve_lr


@register("sgd")
def sgd(lr: Schedule = 0.1, momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        eta = resolve_lr(lr, step)

        def upd(g, p, mu=None):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            if mu is None:
                return -eta * g, None
            mu_new = momentum * mu + g
            d = g + momentum * mu_new if nesterov else mu_new
            return -eta * d, mu_new

        if momentum == 0.0:
            updates = jax.tree.map(lambda g, p: upd(g, p)[0], grads, params)
            return updates, state
        pairs = jax.tree.map(upd, grads, params, state["mu"])
        updates = jax.tree.map(lambda x: x[0], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda x: x[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"mu": mu}

    return Optimizer("sgd", init, update)
