"""LARS — layerwise adaptive rate scaling (survey §3.1.1; You et al. 2017).

Per-layer trust ratio ||w|| / (||g|| + wd·||w||) rescales the learning rate
so large-batch SGD keeps layer updates proportional to layer norms.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer, Schedule, register, resolve_lr


@register("lars")
def lars(lr: Schedule = 1.0, momentum: float = 0.9, weight_decay: float = 1e-4,
         trust_coef: float = 0.001, eps: float = 1e-9) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        eta = resolve_lr(lr, step)

        def upd(g, mu, p):
            g = g.astype(jnp.float32)
            pf = p.astype(jnp.float32)
            g = g + weight_decay * pf
            w_norm = jnp.linalg.norm(pf)
            g_norm = jnp.linalg.norm(g)
            trust = jnp.where(
                (w_norm > 0) & (g_norm > 0),
                trust_coef * w_norm / (g_norm + eps), 1.0)
            mu_new = momentum * mu + eta * trust * g
            return -mu_new, mu_new

        pairs = jax.tree.map(upd, grads, state["mu"], params)
        is_t = lambda x: isinstance(x, tuple)
        return (jax.tree.map(lambda x: x[0], pairs, is_leaf=is_t),
                {"mu": jax.tree.map(lambda x: x[1], pairs, is_leaf=is_t)})

    return Optimizer("lars", init, update)
