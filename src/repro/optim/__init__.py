from repro.optim.base import (  # noqa: F401
    Optimizer, apply_updates, clip_by_global_norm, global_norm, make_optimizer)
from repro.optim import sgd, adam, lars, lamb  # noqa: F401
from repro.optim.sharded import make_sharded_optimizer  # noqa: F401
from repro.optim.schedule import (  # noqa: F401
    constant, legw_warmup_steps, scale_lr_for_batch, warmup_cosine)
