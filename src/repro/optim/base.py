"""Optimizer interface (optax-style, self-contained):

    opt = make_optimizer(name, lr=fn_or_float, **kwargs)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]


def resolve_lr(lr: Schedule, step) -> jnp.ndarray:
    return jnp.asarray(lr(step) if callable(lr) else lr, jnp.float32)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


REGISTRY: Dict[str, Callable[..., Optimizer]] = {}


def register(name: str):
    def deco(fn):
        REGISTRY[name] = fn
        return fn
    return deco


def make_optimizer(name: str, **kwargs) -> Optimizer:
    if name not in REGISTRY:
        raise KeyError(f"unknown optimizer {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name](**kwargs)
