"""Learning-rate schedules for large-batch training (survey §3.1.1):

  * linear / sqrt batch-size scaling rules [Goyal 2017; Krizhevsky 2014]
  * gradual warmup [Goyal 2017]
  * LEGW — linear-epoch gradual warmup [You et al. 2019]: warmup epochs
    scale with the batch-size multiplier k
  * cosine decay (the usual companion)
"""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp


def scale_lr_for_batch(base_lr: float, base_batch: int, batch: int,
                       rule: str = "linear") -> float:
    k = batch / base_batch
    if rule == "linear":
        return base_lr * k
    if rule == "sqrt":
        return base_lr * math.sqrt(k)
    raise ValueError(rule)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  end_lr: float = 0.0) -> Callable:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = end_lr + 0.5 * (peak_lr - end_lr) * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


def legw_warmup_steps(base_warmup_steps: int, base_batch: int, batch: int) -> int:
    """LEGW: multiply warmup length by the batch multiplier k."""
    return int(base_warmup_steps * batch / base_batch)


def constant(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)
