"""Shared model substrate: parameter descriptors, norms, RoPE, MLPs, embeddings.

Parameters are described abstractly (shape + logical axes + init kind) by the
module ``*_desc`` functions, then materialized once by ``materialize`` (values)
and ``partition_specs`` (sharding).  This keeps the sharding layout in one
place and lets ``input_specs``-style dry-runs build ShapeDtypeStructs without
allocating anything.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Parameter descriptors
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamDesc:
    """Abstract parameter: shape, logical axis names, and init kind."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones | small
    scale: Optional[float] = None     # overrides the default fan-in scale

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack_desc(tree, n: int):
    """Prepend a stacked 'layers' dim of size n to every descriptor (for scan)."""
    def f(d: ParamDesc) -> ParamDesc:
        return ParamDesc((n,) + d.shape, ("layers",) + d.axes, d.init, d.scale)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, ParamDesc))


def _init_leaf(key, d: ParamDesc, dtype):
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[-1], 1)
    scale = d.scale if d.scale is not None else 1.0 / np.sqrt(fan_in)
    if d.init == "small":
        scale = 0.02
    return (jax.random.normal(key, d.shape, jnp.float32) * scale).astype(dtype)


def materialize(tree, key, dtype=jnp.float32):
    """Create concrete parameter values for a descriptor tree."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=lambda x: isinstance(x, ParamDesc))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, d, dtype) for k, d in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(tree, dtype=jnp.float32):
    """ShapeDtypeStructs for a descriptor tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), tree,
        is_leaf=lambda x: isinstance(x, ParamDesc))


def partition_specs(tree, rules: Dict[str, Any]):
    """Map logical axes -> mesh axes via ``rules`` (a dict name -> mesh axis
    or None).  Unknown names map to None (replicated).  When two dims of one
    leaf resolve to the same mesh axis (e.g. an (experts, embed, ffn) MoE
    weight with experts->model and ffn->model), only the first keeps it —
    a mesh axis can shard at most one dim."""
    def f(d: ParamDesc) -> P:
        used = set()
        out = []
        for a in d.axes:
            r = rules.get(a) if a is not None else None
            flat = tuple(r) if isinstance(r, tuple) else (r,)
            if r is not None and not (set(flat) & used):
                used.update(flat)
                out.append(r)
            else:
                out.append(None)
        return P(*out)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, ParamDesc))


# Logical-axis -> mesh-axis rule sets.  'fsdp' rules additionally shard the
# d_model ("embed") dim of every weight over the data axis (ZeRO-3 style) so
# multi-10B-parameter configs + Adam state fit 16 GB/chip at train time.
def sharding_rules(phase: str, multi_pod: bool = False) -> Dict[str, Any]:
    data = ("pod", "data") if multi_pod else "data"
    tp = "model"
    if phase == "train":
        return {"vocab": tp, "embed": data, "heads": tp, "kv": tp, "ffn": tp,
                "experts": tp, "layers": None, "lora": None, "state": None,
                "inner": tp}
    # serving: params replicated over data, TP over model only
    return {"vocab": tp, "embed": None, "heads": tp, "kv": tp, "ffn": tp,
            "experts": tp, "layers": None, "lora": None, "state": None,
            "inner": tp}


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_desc(d: int) -> Dict[str, ParamDesc]:
    return {"scale": ParamDesc((d,), (None,), "zeros")}


def rmsnorm(params, x, *, eps: float = 1e-6):
    """RMSNorm with the scale stored as a zero-initialized delta, applied as
    (1 + w) — the gemma convention, equivalent to ones-init standard RMSNorm.
    Statistics in f32."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    y = y * (1.0 + params["scale"].astype(jnp.float32))
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    angles = angles[..., None, :]                              # (..., T, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_desc(d: int, d_ff: int) -> Dict[str, ParamDesc]:
    return {
        "wi_gate": ParamDesc((d, d_ff), ("embed", "ffn")),
        "wi_up": ParamDesc((d, d_ff), ("embed", "ffn")),
        "wo": ParamDesc((d_ff, d), ("ffn", "embed")),
    }


def mlp(params, x, activation: str = "swiglu"):
    act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu
    gate = act(x @ params["wi_gate"])
    up = x @ params["wi_up"]
    return (gate * up) @ params["wo"]


# ---------------------------------------------------------------------------
# Tensor-parallel MLP: the Megatron f/g operator pair (DESIGN.md §14)
# ---------------------------------------------------------------------------
#
# Column-parallel wi then row-parallel wo: each tp rank holds a 1/tp slice
# of the ffn dim and computes its partial output; one allreduce per MLP in
# forward (tp_out) and one in backward (tp_in's transpose) — the classic
# 4-collectives-per-layer wire cost.all_to_all_cost_s's sibling
# ``allreduce_cost_s`` prices in ``tensor_parallel_arm``.
#
# The f/g pair is explicit custom_vjp rather than relying on XLA sharding
# propagation so the wire is OURS: the forward reduction goes through
# ``collectives.api.allreduce`` (any registered algo), and the backward
# activation-grad reduction makes every NON-tp-sharded parameter's gradient
# bit-identical across tp ranks — which is what lets the executor reduce
# all grads over the data axis only, with no tp-specific grad plumbing.

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def tp_in(x, axis: str):
    """Megatron's ``f``: identity forward, psum over the tp ``axis`` in
    backward.  Wrap the activations ENTERING a column-parallel block; the
    backward psum sums the partial input-grads each rank's weight shard
    produced.  psum of the tp group's 2 (or p) partials is a plain
    commutative float add — the bit-exactness checks lean on p=2."""
    return x


def _tp_in_fwd(x, axis):
    return x, None


def _tp_in_bwd(axis, _, g):
    return (jax.lax.psum(g, axis),)


tp_in.defvjp(_tp_in_fwd, _tp_in_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tp_out(x, axis: str, algo: str = "psum"):
    """Megatron's ``g``: allreduce the row-parallel partial output over the
    tp ``axis`` in forward (via ``collectives.api.allreduce`` — any algo),
    identity in backward (the output-grad is already full on every rank)."""
    from repro.core.collectives.api import allreduce
    return allreduce(x, algo, (axis,))


def _tp_out_fwd(x, axis, algo):
    return tp_out(x, axis, algo), None


def _tp_out_bwd(axis, algo, _, g):
    return (g,)


tp_out.defvjp(_tp_out_fwd, _tp_out_bwd)


def mlp_tp(params, x, activation: str = "swiglu", *, axis: str,
           algo: str = "psum"):
    """Tensor-parallel SwiGLU MLP: ``params`` hold this rank's 1/tp slice
    of the ffn dim (wi_gate/wi_up column-sharded, wo row-sharded).  Runs
    inside shard_map with ``axis`` manual; bit-identical at tp=2 to
    :func:`mlp_blocked` with 2 blocks (float add is commutative)."""
    act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu
    xin = tp_in(x, axis)
    gate = act(xin @ params["wi_gate"])
    up = xin @ params["wi_up"]
    return tp_out((gate * up) @ params["wo"], axis, algo)


def mlp_blocked(params, x, activation: str = "swiglu", blocks: int = 2):
    """Reference for the TP conformance checks: the SAME contraction as
    :func:`mlp` but computed in ``blocks`` ffn-slices summed pairwise —
    the arithmetic a tp group performs, on one device.  Each block reads
    ``x`` through an optimization barrier: a tp rank's input-cotangent is
    its two local matmul contributions summed BEFORE the psum across
    ranks, and the barrier forces the same per-block-first association
    here (an unconstrained 4-use fan-out folds in reverse equation order,
    which differs from the tp wire by an ulp)."""
    act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu
    gates = jnp.split(params["wi_gate"], blocks, axis=1)
    ups = jnp.split(params["wi_up"], blocks, axis=1)
    wos = jnp.split(params["wo"], blocks, axis=0)
    parts = []
    for wg, wu, wo in zip(gates, ups, wos):
        xb = jax.lax.optimization_barrier(x)
        parts.append((act(xb @ wg) * (xb @ wu)) @ wo)
    out = parts[0]
    for p in parts[1:]:
        out = out + p
    return out


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_desc(vocab: int, d: int) -> Dict[str, ParamDesc]:
    return {"table": ParamDesc((vocab, d), ("vocab", "embed"), "small")}


def embed(params, tokens, *, scale: bool, d: int):
    x = jnp.take(params["table"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(np.sqrt(d), x.dtype)
    return x


def unembed(params, x, *, softcap: Optional[float] = None):
    logits = x @ params["table"].T
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def unembed_head_desc(vocab: int, d: int) -> Dict[str, ParamDesc]:
    return {"table": ParamDesc((vocab, d), ("vocab", "embed"), "small")}


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_xent(logits, labels, mask=None):
    """Mean token cross-entropy in f32.  labels: int ids; mask optional."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
