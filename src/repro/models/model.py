"""Model facade: one object per architecture config exposing

  * ``param_desc`` / ``init`` / ``partition_specs``
  * ``loss(params, batch)``                      (train)
  * ``prefill(params, batch)``                   (inference prefill)
  * ``decode_step(params, tokens, cache, pos)``  (inference decode)
  * ``input_specs(shape)`` / ``input_partition_specs(shape)``  (dry-run)

covering decoder-only (dense/MoE/SSM/hybrid/VLM) and encoder-decoder (audio)
families.  Cross-entropy is computed in sequence chunks so the full
(B, T, vocab) logits tensor is never materialized.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer
from repro.models.layers import (ParamDesc, abstract_params, embed,
                                 embedding_desc, materialize, norm_desc,
                                 partition_specs, rmsnorm, sharding_rules,
                                 softmax_xent)

XENT_CHUNK = 512


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.plan = cfg.stack_plan()

    # -- parameters ---------------------------------------------------------

    def param_desc(self) -> Dict[str, Any]:
        cfg = self.cfg
        desc: Dict[str, Any] = {
            "embed": embedding_desc(cfg.padded_vocab, cfg.d_model),
            "final_norm": norm_desc(cfg.d_model),
        }
        if cfg.is_encoder_decoder:
            desc["encdec"] = encdec.encdec_desc(cfg)
        else:
            desc["stack"] = transformer.stack_desc_tree(cfg, self.plan)
        if not cfg.tie_embeddings:
            desc["lm_head"] = embedding_desc(cfg.padded_vocab, cfg.d_model)
        return desc

    def init(self, rng, dtype=None):
        dtype = dtype or _dtype(self.cfg.param_dtype)
        return materialize(self.param_desc(), rng, dtype)

    def abstract_params(self, dtype=None):
        dtype = dtype or _dtype(self.cfg.param_dtype)
        return abstract_params(self.param_desc(), dtype)

    def partition_specs(self, phase: str, multi_pod: bool = False):
        rules = sharding_rules(phase, multi_pod)
        return partition_specs(self.param_desc(), rules)

    # -- shared pieces ------------------------------------------------------

    def _embed(self, params, tokens):
        from repro.models.sharding_ctx import constrain
        x = embed(params["embed"], tokens, scale=self.cfg.embed_scale,
                  d=self.cfg.d_model).astype(_dtype(self.cfg.compute_dtype))
        return constrain(x, ("b", None, None))

    def _lm_table(self, params):
        return params["embed" if self.cfg.tie_embeddings else "lm_head"]["table"]

    def _backbone_train(self, params, batch):
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            memory = encdec.encode(params["encdec"], cfg, batch["src"])
            tokens = batch["tokens"]
            x = self._embed(params, tokens)
            positions = jnp.arange(tokens.shape[1])[None, :]
            h = encdec.decode_train(params["encdec"], cfg, x, positions, memory)
            return h, jnp.zeros((), jnp.float32)
        tokens = batch["tokens"]
        x = self._embed(params, tokens)
        positions = jnp.arange(tokens.shape[1])[None, :]
        h, aux = transformer.stack_train(params["stack"], cfg, self.plan, x, positions)
        h = rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
        return h, aux

    def _chunked_xent(self, params, h, labels, mask=None):
        """h: (B, T, d); labels: (B, T). Scan over T chunks; logits are never
        materialized at full length."""
        cfg = self.cfg
        B, T, d = h.shape
        c = min(XENT_CHUNK, T)
        n = T // c
        table = self._lm_table(params)

        @jax.checkpoint
        def chunk_loss(hc, lc):
            # rematerialized in backward: the (B, c, vocab) logits never
            # survive the forward pass
            logits = hc @ table.T
            if cfg.final_logit_softcap:
                logits = cfg.final_logit_softcap * jnp.tanh(
                    logits / cfg.final_logit_softcap)
            mc = lc >= 0
            nll = softmax_xent(logits, jnp.maximum(lc, 0), mc)
            return nll, jnp.sum(mc.astype(jnp.float32))

        def body(carry, i):
            tot, cnt = carry
            hc = jax.lax.dynamic_slice_in_dim(h, i * c, c, axis=1)
            lc = jax.lax.dynamic_slice_in_dim(labels, i * c, c, axis=1)
            nll, k = chunk_loss(hc, lc)
            return (tot + nll * k, cnt + k), None

        (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                     jnp.arange(n))
        rem = T - n * c
        if rem:
            logits = h[:, n * c:] @ table.T
            lc = labels[:, n * c:]
            mc = lc >= 0
            nll = softmax_xent(logits, jnp.maximum(lc, 0), mc)
            k = jnp.sum(mc.astype(jnp.float32))
            tot, cnt = tot + nll * k, cnt + k
        return tot / jnp.maximum(cnt, 1.0)

    # -- training -----------------------------------------------------------

    def loss(self, params, batch):
        """Next-token LM loss (+ MoE aux). Labels are tokens shifted left;
        the final position is masked with -1."""
        tokens = batch["tokens"]
        labels = jnp.concatenate(
            [tokens[:, 1:], -jnp.ones_like(tokens[:, :1])], axis=1)
        h, aux = self._backbone_train(params, batch)
        nll = self._chunked_xent(params, h, labels)
        return nll + self.cfg.router_aux_coef * aux

    # -- inference ----------------------------------------------------------

    def prefill(self, params, batch, max_len: Optional[int] = None):
        """Returns (last-token logits, cache)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, T = tokens.shape
        max_len = max_len or T
        x = self._embed(params, tokens)
        positions = jnp.arange(T)[None, :]
        if cfg.is_encoder_decoder:
            memory = encdec.encode(params["encdec"], cfg, batch["src"])
            h, cache = encdec.decode_prefill(params["encdec"], cfg, x, positions,
                                             memory, max_len)
        else:
            h, _, cache = transformer.stack_prefill(params["stack"], cfg, self.plan,
                                                    x, positions, max_len)
            h = rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
        logits = h[:, -1:] @ self._lm_table(params).T
        if cfg.final_logit_softcap:
            logits = cfg.final_logit_softcap * jnp.tanh(
                logits / cfg.final_logit_softcap)
        return logits, cache

    def init_cache(self, batch: int, max_len: int, src_len: int = 0, dtype=None):
        cfg = self.cfg
        dtype = dtype or _dtype(cfg.compute_dtype)
        if cfg.is_encoder_decoder:
            one = encdec.dec_block_cache(cfg, batch, max_len, src_len, dtype)
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((cfg.num_layers,) + s.shape, s.dtype),
                one)
        return transformer.stack_cache(cfg, self.plan, batch, max_len, dtype)

    def decode_step(self, params, tokens, cache, pos, mla_absorb: bool = False,
                    moe_dispatch: bool = False):
        """tokens: (B, 1) int32; pos: scalar int32 (tokens already cached)
        or an (B,) int32 vector of per-row depths (continuous batching —
        every serving slot decodes at its own position; DESIGN.md §12).
        Returns (logits (B, 1, vocab), new_cache)."""
        cfg = self.cfg
        x = self._embed(params, tokens)
        if cfg.is_encoder_decoder:
            h, new_cache = encdec.decode_step_stack(params["encdec"], cfg, x,
                                                    cache, pos)
        else:
            h, new_cache = transformer.stack_decode(params["stack"], cfg, self.plan,
                                                    x, cache, pos, mla_absorb,
                                                    moe_dispatch)
            h = rmsnorm(params["final_norm"], h, eps=cfg.norm_eps)
        logits = h @ self._lm_table(params).T
        if cfg.final_logit_softcap:
            logits = cfg.final_logit_softcap * jnp.tanh(
                logits / cfg.final_logit_softcap)
        return logits, new_cache

    # -- dry-run specs ------------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every step-function input."""
        cfg = self.cfg
        B, T = shape.global_batch, shape.seq_len
        cdt = _dtype(cfg.compute_dtype)
        if shape.phase in ("train", "prefill"):
            specs = {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32)}
            if cfg.is_encoder_decoder:
                specs["src"] = jax.ShapeDtypeStruct((B, T, cfg.d_model), cdt)
            return specs
        # decode: one new token against a T-entry cache
        src_len = T if cfg.is_encoder_decoder else 0
        return {
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "cache": self.init_cache(B, T, src_len=src_len, dtype=cdt),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def input_partition_specs(self, shape: ShapeConfig, multi_pod: bool = False):
        """PartitionSpecs matching input_specs()."""
        cfg = self.cfg
        data = ("pod", "data") if multi_pod else "data"
        B = shape.global_batch
        batch_axis = data if B > 1 else None
        if shape.phase in ("train", "prefill"):
            specs = {"tokens": P(batch_axis, None)}
            if cfg.is_encoder_decoder:
                specs["src"] = P(batch_axis, None, None)
            return specs
        # decode cache sharding (name-based; see DESIGN.md §3):
        #   * batch over the data axes (when B > 1)
        #   * attention K/V: KV-head dim over 'model' when divisible, else the
        #     cache LENGTH over 'model' (sequence-parallel decode — partial
        #     attention per shard, softmax/psum combine handled by SPMD)
        #   * MLA latents: length over 'model'
        #   * recurrent states: d_inner over 'model'
        #   * B == 1 long-context: length over 'data' too (flash-decoding
        #     style maximum parallelism)
        model_n = 16  # production model-axis size (no-op on smaller meshes)

        def cache_spec(path, s: jax.ShapeDtypeStruct) -> P:
            name = next((str(p.key) for p in reversed(path)
                         if hasattr(p, "key")), "")
            nd = len(s.shape)
            spec = [None] * nd
            bi = next((i for i in range(min(nd, 2)) if s.shape[i] == B), None)
            if bi is None:
                return P(*spec)
            if B > 1:
                spec[bi] = batch_axis
            li = bi + 1  # length dim, when the leaf has one
            if name in ("k", "v", "cross_k", "cross_v"):
                kv_dim = bi + 2
                if s.shape[kv_dim] % model_n == 0:
                    spec[kv_dim] = "model"
                elif li < nd and s.shape[li] % model_n == 0 and s.shape[li] >= 2048:
                    spec[li] = "model"
            elif name in ("c_kv", "k_rope"):
                if li < nd and s.shape[li] % model_n == 0 and s.shape[li] >= 2048:
                    spec[li] = "model"
            elif name in ("h", "conv", "C"):
                fi = max(range(bi + 1, nd), key=lambda i: s.shape[i])
                if s.shape[fi] % model_n == 0:
                    spec[fi] = "model"
            if B == 1 and li < nd and s.shape[li] >= 4096:
                axes = list(data) if isinstance(data, tuple) else [data]
                if spec[li] is None:
                    spec[li] = tuple(axes)
                elif spec[li] == "model":
                    spec[li] = tuple(axes) + ("model",)
            return P(*spec)

        cache = jax.tree_util.tree_map_with_path(cache_spec, self.init_cache(
            B, shape.seq_len, src_len=shape.seq_len if cfg.is_encoder_decoder else 0,
            dtype=_dtype(cfg.compute_dtype)))
        return {"tokens": P(batch_axis, None), "cache": cache, "pos": P()}


# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig) -> int:
    model = Model(cfg)
    leaves = jax.tree.leaves(model.param_desc(),
                             is_leaf=lambda x: isinstance(x, ParamDesc))
    return int(sum(np.prod(l.shape) for l in leaves))
