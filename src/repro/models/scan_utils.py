"""Chunked, remat-friendly time scans.

A plain ``lax.scan`` over T steps saves every carry for the backward pass —
for recurrences with large state (mLSTM's (B, H, dh, dh) matrix memory,
Mamba's (B, d_inner, d_state)) that is O(T · state) and blows past HBM at
T = 4k-32k.  ``chunked_scan`` reshapes time into (T/c) chunks, scans over
chunks, and rematerializes within each chunk (``jax.checkpoint``), so the
backward pass stores only T/c boundary states + one chunk of recompute —
the TPU-native equivalent of the fused CUDA recurrence kernels
(DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_scan(step, init, xs, chunk: int, checkpoint_step: bool = True):
    """Equivalent to ``jax.lax.scan(step, init, xs)`` with bounded backward
    memory.  ``checkpoint_step`` additionally remats each step body so the
    backward pass stores one CARRY per step (not every step residual) —
    essential when the step computes large intermediates against a large
    recurrent state.  All leading dims of xs leaves must equal T and be
    divisible by ``chunk`` (callers pad if needed)."""
    body = jax.checkpoint(step) if checkpoint_step else step
    leaves = jax.tree.leaves(xs)
    T = leaves[0].shape[0]
    if chunk >= T or T % chunk != 0:
        # non-divisible lengths (arbitrary serving prompts): plain scan —
        # fine at the small sizes where this happens
        return jax.lax.scan(body, init, xs)
    nc = T // chunk
    xs_c = jax.tree.map(lambda x: x.reshape((nc, chunk) + x.shape[1:]), xs)

    @jax.checkpoint
    def inner(carry, xc):
        return jax.lax.scan(body, carry, xc)

    carry, ys_c = jax.lax.scan(inner, init, xs_c)
    ys = jax.tree.map(lambda y: y.reshape((T,) + y.shape[2:]), ys_c)
    return carry, ys
