"""Mixture-of-Experts FFN with top-k routing, shared experts, capacity-based
dispatch, and a switch-style load-balance auxiliary loss.

Dispatch is the sort-free capacity scheme: each token's k choices are given a
slot inside the chosen expert's capacity buffer via a cumulative-sum over the
one-hot routing matrix; tokens overflowing capacity are dropped (standard
practice, capacity_factor controls the drop rate).  With experts sharded over
the ``model`` mesh axis the scatter/gather lower to all-to-all style
collectives — the expert-parallel pattern the survey's §4 discusses.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDesc, mlp, mlp_desc
from repro.models.sharding_ctx import constrain


def moe_desc(cfg: ModelConfig) -> Dict[str, ParamDesc]:
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    desc = {
        "router": ParamDesc((d, E), ("embed", None), "small"),
        "wi_gate": ParamDesc((E, d, ff), ("experts", "embed", "ffn")),
        "wi_up": ParamDesc((E, d, ff), ("experts", "embed", "ffn")),
        "wo": ParamDesc((E, ff, d), ("experts", "ffn", "embed")),
    }
    if cfg.num_shared_experts:
        desc["shared"] = mlp_desc(d, ff * cfg.num_shared_experts)
    return desc


def _route(cfg: ModelConfig, logits: jnp.ndarray):
    """logits: (N, E) -> (weights (N,k), experts (N,k), aux_loss)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, experts = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # switch-style load balance: E * sum_e f_e * p_e
    E = logits.shape[-1]
    one_hot = jax.nn.one_hot(experts[..., 0], E, dtype=jnp.float32)
    f = one_hot.mean(0)
    p = probs.mean(0)
    aux = E * jnp.sum(f * p)
    return weights, experts, aux


def moe_ffn(params, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d) -> (out, aux_loss).

    Tokens are grouped per data shard (per-group capacity — real
    expert-parallel per-rank semantics).  The scatter/gather run under
    ``vmap`` over the group dim, which makes G a scatter BATCH dimension the
    SPMD partitioner can shard over the data axes; the expert einsums keep
    explicit (G, E, cap, ·) shapes with G over 'b' and E over 'model' — the
    expert-parallel all-to-all pattern of survey §4."""
    from repro.models.sharding_ctx import num_batch_shards
    B, T, d = x.shape
    N = B * T
    E, k = cfg.num_experts, cfg.top_k
    cdt = x.dtype
    G = num_batch_shards()
    if N % G:
        G = 1
    ng = N // G
    cap = int(max(1, ng * k / E * cfg.capacity_factor))

    xf = constrain(x.reshape(N, d), ("b", None))
    weights, experts, aux = _route(cfg, xf @ params["router"])

    eg = constrain(experts.reshape(G, ng * k), ("b", None))
    wg = weights.reshape(G, ng * k)
    onehot = constrain(jax.nn.one_hot(eg, E, dtype=jnp.int32), ("b", None, None))
    slot = (jnp.cumsum(onehot, axis=1) - 1) * onehot              # per-group
    flat_slot = slot.sum(-1)
    keep = flat_slot < cap
    dest = jnp.where(keep, eg * cap + flat_slot, E * cap)         # (G, ng*k)

    tok_idx = jnp.repeat(jnp.arange(ng), k)
    xg = constrain(xf.reshape(G, ng, d), ("b", None, None))
    src = constrain(jnp.take(xg, tok_idx, axis=1), ("b", None, None))

    def scatter_one(s, idx):
        return jnp.zeros((E * cap + 1, d), cdt).at[idx].set(s)[: E * cap]

    buf = jax.vmap(scatter_one)(src, dest)                        # (G, E*cap, d)
    buf = constrain(buf.reshape(G, E, cap, d), ("b", "m", None, None))

    h_gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["wi_gate"]))
    h_up = jnp.einsum("gecd,edf->gecf", buf, params["wi_up"])
    h_mid = constrain((h_gate * h_up).astype(cdt), ("b", "m", None, None))
    out_buf = constrain(jnp.einsum("gecf,efd->gecd", h_mid, params["wo"]),
                        ("b", "m", None, None))
    out_flat = constrain(out_buf.reshape(G, E * cap, d), ("b", None, None))

    def gather_one(flat, idx, kp):
        g = jnp.take(flat, jnp.minimum(idx, E * cap - 1), axis=0)
        return jnp.where(kp[:, None], g, 0.0)

    gathered = jax.vmap(gather_one)(out_flat, dest, keep)         # (G, ng*k, d)
    contrib = gathered * wg[..., None].astype(gathered.dtype)

    def combine_one(c):
        return jnp.zeros((ng, d), cdt).at[tok_idx].add(c)

    out = constrain(jax.vmap(combine_one)(contrib), ("b", None, None))
    out = out.reshape(N, d)

    if cfg.num_shared_experts:
        out = out + mlp(params["shared"], xf, cfg.activation)
    return out.reshape(B, T, d), aux


def moe_decode_ffn(params, cfg: ModelConfig, x) -> jnp.ndarray:
    """Single-token path (B, 1, d): gather the k selected experts' weights per
    token instead of capacity dispatch — decode batches are tiny so the
    gather is cheap and drop-free."""
    B, _, d = x.shape
    xf = x.reshape(B, d)
    weights, experts, _ = _route(cfg, xf @ params["router"])       # (B,k)
    wg = params["wi_gate"][experts]                                # (B,k,d,ff)
    wu = params["wi_up"][experts]
    wo = params["wo"][experts]                                     # (B,k,ff,d)
    h = jax.nn.silu(jnp.einsum("bd,bkdf->bkf", xf, wg)) * jnp.einsum(
        "bd,bkdf->bkf", xf, wu)
    out = jnp.einsum("bkf,bkfd->bkd", h, wo)
    out = jnp.einsum("bkd,bk->bd", out, weights.astype(out.dtype))
    if cfg.num_shared_experts:
        out = out + mlp(params["shared"], xf, cfg.activation)
    return out.reshape(B, 1, d)
