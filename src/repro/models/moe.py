"""Mixture-of-Experts FFN with top-k routing, shared experts, capacity-based
dispatch, and a switch-style load-balance auxiliary loss.

Dispatch is the sort-free capacity scheme: each token's k choices are given a
slot inside the chosen expert's capacity buffer via a cumulative-sum over the
one-hot routing matrix; tokens overflowing capacity are dropped (standard
practice, capacity_factor controls the drop rate).  With experts sharded over
the ``model`` mesh axis the scatter/gather lower to all-to-all style
collectives — the expert-parallel pattern the survey's §4 discusses.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParamDesc, mlp, mlp_desc
from repro.models.sharding_ctx import constrain


# ---------------------------------------------------------------------------
# Dropped-token tap (ISSUE 9: capacity overflow must not vanish silently)
# ---------------------------------------------------------------------------
#
# Capacity dispatch DROPS tokens that overflow an expert's buffer; with
# capacity_factor near 1 under a skewed router that is real signal loss the
# step log used to hide.  The tap is a host-side accumulator fed by
# ``jax.debug.callback`` — the only side channel that crosses jit/grad/scan
# without changing every loss signature between here and the train loop.
# Toggling changes the traced program, so enable it BEFORE the first step
# compiles (TrainSession does this for MoE archs); counts drain per step via
# ``drain_drop_tap``.

_DROP_TAP = {"enabled": False, "dropped": 0.0, "routed": 0.0}


def enable_drop_tap(enable: bool = True) -> bool:
    """Turn the tap on/off (returns the previous state).  Must happen
    before tracing: the callback is baked into the jitted program."""
    old = _DROP_TAP["enabled"]
    _DROP_TAP["enabled"] = bool(enable)
    return old


def drain_drop_tap() -> Tuple[float, float]:
    """Return ``(dropped, routed)`` token-choice counts accumulated since
    the last drain, and reset.  Callers must block on the step's outputs
    first (e.g. ``float(loss)``) so the callbacks have fired."""
    d, r = _DROP_TAP["dropped"], _DROP_TAP["routed"]
    _DROP_TAP["dropped"] = _DROP_TAP["routed"] = 0.0
    return d, r


def _drop_tap_cb(dropped, routed: float):
    _DROP_TAP["dropped"] += float(dropped)
    _DROP_TAP["routed"] += float(routed)


def moe_desc(cfg: ModelConfig) -> Dict[str, ParamDesc]:
    d = cfg.d_model
    ff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    desc = {
        "router": ParamDesc((d, E), ("embed", None), "small"),
        "wi_gate": ParamDesc((E, d, ff), ("experts", "embed", "ffn")),
        "wi_up": ParamDesc((E, d, ff), ("experts", "embed", "ffn")),
        "wo": ParamDesc((E, ff, d), ("experts", "ffn", "embed")),
    }
    if cfg.num_shared_experts:
        desc["shared"] = mlp_desc(d, ff * cfg.num_shared_experts)
    return desc


def _route(cfg: ModelConfig, logits: jnp.ndarray):
    """logits: (N, E) -> (weights (N,k), experts (N,k), aux_loss)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, experts = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # switch-style load balance: E * sum_e f_e * p_e
    E = logits.shape[-1]
    one_hot = jax.nn.one_hot(experts[..., 0], E, dtype=jnp.float32)
    f = one_hot.mean(0)
    p = probs.mean(0)
    aux = E * jnp.sum(f * p)
    return weights, experts, aux


def moe_ffn(params, cfg: ModelConfig, x, *,
            groups: Optional[int] = None,
            ep_axis: Optional[str] = None,
            a2a_variant: str = "direct"
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d) -> (out, aux_loss).

    Tokens are grouped per data shard (per-group capacity — real
    expert-parallel per-rank semantics).  The scatter/gather run under
    ``vmap`` over the group dim, which makes G a scatter BATCH dimension the
    SPMD partitioner can shard over the data axes; the expert einsums keep
    explicit (G, E, cap, ·) shapes with G over 'b' and E over 'model' — the
    expert-parallel all-to-all pattern of survey §4.

    ``groups`` overrides the context-derived group count (the conformance
    checks use it to mirror an ep group's source batching on one device).

    ``ep_axis`` names a manual shard_map axis carrying TRUE expert
    parallelism (DESIGN.md §14): ``params`` hold only this rank's
    ``E/ep`` expert block (router replicated, routing still over global
    E), the capacity buffer is exchanged over the wire with
    ``collectives.api.all_to_all`` (dispatch), the local experts run, and
    the reverse all-to-all (combine — also the edge autodiff inserts for
    the backward pass) returns every token's output to its owner.  Chunks
    move verbatim, so the EP step is bit-identical to the same math on
    one device with source-batched groups."""
    from repro.models.sharding_ctx import num_batch_shards
    B, T, d = x.shape
    N = B * T
    E, k = cfg.num_experts, cfg.top_k
    cdt = x.dtype
    G = groups if groups is not None else num_batch_shards()
    if N % G:
        G = 1
    ng = N // G
    cap = int(max(1, ng * k / E * cfg.capacity_factor))
    ep = 1
    if ep_axis is not None:
        ep = jax.lax.axis_size(ep_axis)
        if G != 1:
            raise ValueError(f"ep_axis={ep_axis!r} wants one token group "
                             f"per rank, got G={G} (the rank IS the group)")
        if E % ep:
            raise ValueError(f"num_experts={E} not divisible by "
                             f"ep={ep} ({ep_axis!r})")
        if params["wi_gate"].shape[0] != E // ep:
            raise ValueError(
                f"expert-parallel moe_ffn wants the LOCAL expert block "
                f"({E // ep} of {E}), got params with "
                f"{params['wi_gate'].shape[0]} experts")

    xf = constrain(x.reshape(N, d), ("b", None))
    weights, experts, aux = _route(cfg, xf @ params["router"])

    eg = constrain(experts.reshape(G, ng * k), ("b", None))
    wg = weights.reshape(G, ng * k)
    onehot = constrain(jax.nn.one_hot(eg, E, dtype=jnp.int32), ("b", None, None))
    slot = (jnp.cumsum(onehot, axis=1) - 1) * onehot              # per-group
    flat_slot = slot.sum(-1)
    keep = flat_slot < cap
    dest = jnp.where(keep, eg * cap + flat_slot, E * cap)         # (G, ng*k)
    if _DROP_TAP["enabled"]:
        # host callbacks abort XLA inside a PARTIAL-manual shard_map body
        # (manual data axes + a live auto model axis); skip the tap there
        # rather than crash — counts then read 0 and the summary stays
        # silent for that (programmatic, model>1) configuration
        from repro.models.sharding_ctx import host_callback_safe
        if host_callback_safe():
            jax.debug.callback(_drop_tap_cb, (~keep).sum(), float(keep.size))

    tok_idx = jnp.repeat(jnp.arange(ng), k)
    xg = constrain(xf.reshape(G, ng, d), ("b", None, None))
    src = constrain(jnp.take(xg, tok_idx, axis=1), ("b", None, None))

    def scatter_one(s, idx):
        return jnp.zeros((E * cap + 1, d), cdt).at[idx].set(s)[: E * cap]

    buf = jax.vmap(scatter_one)(src, dest)                        # (G, E*cap, d)
    buf = constrain(buf.reshape(G, E, cap, d), ("b", "m", None, None))

    if ep_axis is not None:
        from repro.core.collectives.api import all_to_all
        El = E // ep
        # dispatch: chunk s of the capacity buffer is the payload for ep
        # rank s (its expert block, GLOBAL expert order = rank-major)
        b = all_to_all(buf.reshape(ep, El * cap, d), ep_axis, a2a_variant)
        b = b.reshape(ep, El, cap, d)         # row s: source rank s's tokens
        h_gate = jax.nn.silu(jnp.einsum("secd,edf->secf", b,
                                        params["wi_gate"]))
        h_up = jnp.einsum("secd,edf->secf", b, params["wi_up"])
        h_mid = (h_gate * h_up).astype(cdt)
        out_b = jnp.einsum("secf,efd->secd", h_mid, params["wo"])
        # combine: the reverse all-to-all returns each token's outputs to
        # its owner, re-assembling the (E, cap, d) buffer in global order
        out_flat = all_to_all(out_b.reshape(ep, El * cap, d), ep_axis,
                              a2a_variant).reshape(G, E * cap, d)
    else:
        h_gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf,
                                        params["wi_gate"]))
        h_up = jnp.einsum("gecd,edf->gecf", buf, params["wi_up"])
        h_mid = constrain((h_gate * h_up).astype(cdt),
                          ("b", "m", None, None))
        out_buf = constrain(jnp.einsum("gecf,efd->gecd", h_mid,
                                       params["wo"]),
                            ("b", "m", None, None))
        out_flat = constrain(out_buf.reshape(G, E * cap, d),
                             ("b", None, None))

    def gather_one(flat, idx, kp):
        g = jnp.take(flat, jnp.minimum(idx, E * cap - 1), axis=0)
        return jnp.where(kp[:, None], g, 0.0)

    gathered = jax.vmap(gather_one)(out_flat, dest, keep)         # (G, ng*k, d)
    contrib = gathered * wg[..., None].astype(gathered.dtype)

    def combine_one(c):
        return jnp.zeros((ng, d), cdt).at[tok_idx].add(c)

    out = constrain(jax.vmap(combine_one)(contrib), ("b", None, None))
    out = out.reshape(N, d)

    if cfg.num_shared_experts:
        out = out + mlp(params["shared"], xf, cfg.activation)
    return out.reshape(B, T, d), aux


def moe_decode_ffn(params, cfg: ModelConfig, x) -> jnp.ndarray:
    """Single-token path (B, 1, d): gather the k selected experts' weights per
    token instead of capacity dispatch — decode batches are tiny so the
    gather is cheap and drop-free."""
    B, _, d = x.shape
    xf = x.reshape(B, d)
    weights, experts, _ = _route(cfg, xf @ params["router"])       # (B,k)
    wg = params["wi_gate"][experts]                                # (B,k,d,ff)
    wu = params["wi_up"][experts]
    wo = params["wo"][experts]                                     # (B,k,ff,d)
    h = jax.nn.silu(jnp.einsum("bd,bkdf->bkf", xf, wg)) * jnp.einsum(
        "bd,bkdf->bkf", xf, wu)
    out = jnp.einsum("bkf,bkfd->bkd", h, wo)
    out = jnp.einsum("bkd,bk->bd", out, weights.astype(out.dtype))
    if cfg.num_shared_experts:
        out = out + mlp(params["shared"], xf, cfg.activation)
    return out.reshape(B, 1, d)
