"""Encoder-decoder stack for SeamlessM4T-large-v2.

The speech frontend (mel-spectrogram + conformer feature extractor) is the
allowed modality STUB: the encoder consumes precomputed frame embeddings
(B, S, d_model).  The encoder is a bidirectional transformer; the decoder is
a causal transformer with cross-attention over the encoder memory.  Decode
caches both the self-attention KV and the (constant) projected cross KV.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as attn
from repro.models.layers import ParamDesc, mlp, mlp_desc, norm_desc, rmsnorm
from repro.models.transformer import stack_desc

CROSS_SPEC = LayerSpec(mixer="attn", window=None, ffn="dense")


def cross_attn_desc(cfg: ModelConfig) -> Dict[str, ParamDesc]:
    d, hd = cfg.d_model, cfg.hd
    return {
        "wq": ParamDesc((d, cfg.num_heads * hd), ("embed", "heads")),
        "wk": ParamDesc((d, cfg.num_kv_heads * hd), ("embed", "kv")),
        "wv": ParamDesc((d, cfg.num_kv_heads * hd), ("embed", "kv")),
        "wo": ParamDesc((cfg.num_heads * hd, d), ("heads", "embed")),
    }


def cross_kv(params, cfg: ModelConfig, memory):
    B, S, _ = memory.shape
    k = (memory @ params["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.hd)
    v = (memory @ params["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.hd)
    return k, v


def cross_attend(params, cfg: ModelConfig, x, k, v):
    """x: (B, T, d); k, v: (B, S, KV, hd). No mask, no RoPE (enc-dec)."""
    B, T, _ = x.shape
    q = (x @ params["wq"]).reshape(B, T, cfg.num_heads, cfg.hd)
    out = attn.flash_attention(q, k, v, causal=False)
    return out.reshape(B, T, -1) @ params["wo"]


def dec_block_desc(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "norm1": norm_desc(cfg.d_model),
        "self": attn.attn_desc(cfg),
        "norm_x": norm_desc(cfg.d_model),
        "cross": cross_attn_desc(cfg),
        "norm2": norm_desc(cfg.d_model),
        "ffn": mlp_desc(cfg.d_model, cfg.d_ff),
    }


def dec_block_train(params, cfg: ModelConfig, x, positions, memory):
    h = rmsnorm(params["norm1"], x, eps=cfg.norm_eps)
    x = x + attn.attn_forward(params["self"], cfg, CROSS_SPEC, h, positions)
    h = rmsnorm(params["norm_x"], x, eps=cfg.norm_eps)
    k, v = cross_kv(params["cross"], cfg, memory)
    x = x + cross_attend(params["cross"], cfg, h, k, v)
    h = rmsnorm(params["norm2"], x, eps=cfg.norm_eps)
    return x + mlp(params["ffn"], h, cfg.activation)


def dec_block_prefill(params, cfg: ModelConfig, x, positions, memory, max_len):
    h = rmsnorm(params["norm1"], x, eps=cfg.norm_eps)
    sa, self_cache = attn.attn_prefill(params["self"], cfg, CROSS_SPEC, h,
                                       positions, max_len)
    x = x + sa
    h = rmsnorm(params["norm_x"], x, eps=cfg.norm_eps)
    k, v = cross_kv(params["cross"], cfg, memory)
    x = x + cross_attend(params["cross"], cfg, h, k, v)
    h = rmsnorm(params["norm2"], x, eps=cfg.norm_eps)
    x = x + mlp(params["ffn"], h, cfg.activation)
    return x, {"self": self_cache, "cross_k": k, "cross_v": v}


def dec_block_cache(cfg: ModelConfig, batch: int, max_len: int, src_len: int, dtype):
    self_cache = attn.init_attn_cache(cfg, CROSS_SPEC, batch, max_len, dtype)
    kv = jax.ShapeDtypeStruct((batch, src_len, cfg.num_kv_heads, cfg.hd), dtype)
    return {"self": self_cache, "cross_k": kv, "cross_v": kv}


def dec_block_decode(params, cfg: ModelConfig, x, cache, pos):
    h = rmsnorm(params["norm1"], x, eps=cfg.norm_eps)
    sa, self_cache = attn.attn_decode(params["self"], cfg, CROSS_SPEC, h,
                                      cache["self"], pos)
    x = x + sa
    h = rmsnorm(params["norm_x"], x, eps=cfg.norm_eps)
    x = x + cross_attend(params["cross"], cfg, h, cache["cross_k"], cache["cross_v"])
    h = rmsnorm(params["norm2"], x, eps=cfg.norm_eps)
    x = x + mlp(params["ffn"], h, cfg.activation)
    return x, {"self": self_cache, "cross_k": cache["cross_k"],
               "cross_v": cache["cross_v"]}


# ---------------------------------------------------------------------------
# Stacks (uniform layers -> one scan each)
# ---------------------------------------------------------------------------

def encdec_desc(cfg: ModelConfig) -> Dict[str, Any]:
    from repro.models.transformer import block_desc
    enc_spec = LayerSpec(mixer="attn", window=None, ffn="dense")
    enc_block = block_desc(cfg, enc_spec)
    dec_block = dec_block_desc(cfg)
    return {
        "enc_stack": stack_desc(enc_block, cfg.num_encoder_layers),
        "enc_norm": norm_desc(cfg.d_model),
        "dec_stack": stack_desc(dec_block, cfg.num_layers),
        "dec_norm": norm_desc(cfg.d_model),
    }


def encode(params, cfg: ModelConfig, src):
    """src: (B, S, d) precomputed frame embeddings (frontend stub)."""
    from repro.models.transformer import block_train
    enc_spec = LayerSpec(mixer="attn", window=None, ffn="dense")
    B, S, _ = src.shape
    positions = jnp.arange(S)[None, :]

    @jax.checkpoint
    def body_fn(h, p):
        h, _ = block_train(p, cfg, enc_spec, h, positions, causal=False)
        return h

    x, _ = jax.lax.scan(lambda h, p: (body_fn(h, p), None), src,
                        params["enc_stack"])
    return rmsnorm(params["enc_norm"], x, eps=cfg.norm_eps)


def decode_train(params, cfg: ModelConfig, x, positions, memory):
    @jax.checkpoint
    def body_fn(h, p):
        return dec_block_train(p, cfg, h, positions, memory)

    x, _ = jax.lax.scan(lambda h, p: (body_fn(h, p), None), x,
                        params["dec_stack"])
    return rmsnorm(params["dec_norm"], x, eps=cfg.norm_eps)


def decode_prefill(params, cfg: ModelConfig, x, positions, memory, max_len):
    def body(h, p):
        h, cache = dec_block_prefill(p, cfg, h, positions, memory, max_len)
        return h, cache

    x, caches = jax.lax.scan(body, x, params["dec_stack"])
    return rmsnorm(params["dec_norm"], x, eps=cfg.norm_eps), caches


def decode_step_stack(params, cfg: ModelConfig, x, caches, pos):
    def body(h, inp):
        p, c = inp
        h, nc = dec_block_decode(p, cfg, h, c, pos)
        return h, nc

    x, new_caches = jax.lax.scan(body, x, (params["dec_stack"], caches))
    return rmsnorm(params["dec_norm"], x, eps=cfg.norm_eps), new_caches
