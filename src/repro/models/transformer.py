"""Decoder stack: config-driven blocks (attention / MLA / Mamba / xLSTM ×
dense / MoE FFN), lowered as ``lax.scan`` over repeating layer periods so HLO
size stays O(period) instead of O(num_layers).

Three entry points per stack:
  * ``forward_train``  — full-sequence, returns (hidden, aux_loss)
  * ``forward_prefill``— full-sequence, additionally returns the decode cache
  * ``decode_step``    — one token against the cache (B, 1, d)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig, Segment
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (ParamDesc, mlp, mlp_desc, mlp_tp, norm_desc,
                                 rmsnorm, stack_desc)
from repro.models.sharding_ctx import constrain, tp_axis


# ---------------------------------------------------------------------------
# One block
# ---------------------------------------------------------------------------

def block_desc(cfg: ModelConfig, spec: LayerSpec) -> Dict[str, Any]:
    desc: Dict[str, Any] = {}
    if spec.mixer in ("mlstm", "slstm"):
        # xLSTM blocks carry their own norms and FFN
        desc["mixer"] = (xlstm_mod.mlstm_desc(cfg) if spec.mixer == "mlstm"
                         else xlstm_mod.slstm_desc(cfg))
        return desc
    desc["norm1"] = norm_desc(cfg.d_model)
    if spec.mixer == "attn":
        desc["mixer"] = attn.attn_desc(cfg)
    elif spec.mixer == "mla":
        desc["mixer"] = attn.mla_desc(cfg)
    elif spec.mixer == "mamba":
        desc["mixer"] = ssm_mod.mamba_desc(cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        desc["norm2"] = norm_desc(cfg.d_model)
        desc["ffn"] = (moe_mod.moe_desc(cfg) if spec.ffn == "moe"
                       else mlp_desc(cfg.d_model, cfg.d_ff))
    return desc


def _boundary(h):
    """Block-boundary barrier: stops XLA hoisting the next norm's f32
    upcast through the tensor-parallel partial-sum all-reduce — keeps those
    activation reductions in bf16 (2x wire; see EXPERIMENTS.md §Perf)."""
    return jax.lax.optimization_barrier(h)


def block_train(params, cfg: ModelConfig, spec: LayerSpec, x, positions,
                causal: bool = True):
    """Full-sequence block. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if spec.mixer in ("mlstm", "slstm"):
        f = xlstm_mod.mlstm_forward if spec.mixer == "mlstm" else xlstm_mod.slstm_forward
        return x + _boundary(f(params["mixer"], cfg, x)), aux
    h = rmsnorm(params["norm1"], x, eps=cfg.norm_eps)
    if spec.mixer == "attn":
        if causal:
            h = attn.attn_forward(params["mixer"], cfg, spec, h, positions)
        else:  # encoder self-attention
            h = _attn_bidirectional(params["mixer"], cfg, spec, h, positions)
    elif spec.mixer == "mla":
        h = attn.mla_forward(params["mixer"], cfg, spec, h, positions)
    else:  # mamba
        h = ssm_mod.mamba_forward(params["mixer"], cfg, h)
    x = x + _boundary(h)
    if spec.ffn != "none":
        h = rmsnorm(params["norm2"], x, eps=cfg.norm_eps)
        if spec.ffn == "moe":
            h, aux = moe_mod.moe_ffn(params["ffn"], cfg, h)
        elif tp_axis():
            # manual tensor parallelism (DESIGN.md §14): params hold this
            # rank's ffn slice; the Megatron f/g wire reduces activations
            # over the tp axis via collectives.api
            h = mlp_tp(params["ffn"], h, cfg.activation, axis=tp_axis())
        else:
            h = mlp(params["ffn"], h, cfg.activation)
        x = x + _boundary(h)
    return x, aux


def _attn_bidirectional(params, cfg, spec, x, positions):
    B, T, _ = x.shape
    q, k, v = attn._project_qkv(params, cfg, x, positions)
    out = attn.flash_attention(q, k, v, causal=False, window=spec.window,
                               softcap=cfg.attn_logit_softcap)
    return out.reshape(B, T, -1) @ params["wo"]


def block_prefill(params, cfg: ModelConfig, spec: LayerSpec, x, positions,
                  max_len: int):
    """Full-sequence block that also emits this layer's decode cache."""
    aux = jnp.zeros((), jnp.float32)
    if spec.mixer in ("mlstm", "slstm"):
        f = xlstm_mod.mlstm_forward if spec.mixer == "mlstm" else xlstm_mod.slstm_forward
        h, cache = f(params["mixer"], cfg, x, return_state=True)
        return x + h, aux, cache
    h = rmsnorm(params["norm1"], x, eps=cfg.norm_eps)
    if spec.mixer == "attn":
        h, cache = attn.attn_prefill(params["mixer"], cfg, spec, h, positions, max_len)
    elif spec.mixer == "mla":
        h, cache = attn.mla_prefill(params["mixer"], cfg, spec, h, positions, max_len)
    else:
        h, cache = ssm_mod.mamba_forward(params["mixer"], cfg, h, return_state=True)
    x = x + h
    if spec.ffn != "none":
        h = rmsnorm(params["norm2"], x, eps=cfg.norm_eps)
        if spec.ffn == "moe":
            h, aux = moe_mod.moe_ffn(params["ffn"], cfg, h)
        else:
            h = mlp(params["ffn"], h, cfg.activation)
        x = x + h
    return x, aux, cache


def block_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int,
                dtype) -> Optional[Dict[str, jax.ShapeDtypeStruct]]:
    if spec.mixer == "attn":
        return attn.init_attn_cache(cfg, spec, batch, max_len, dtype)
    if spec.mixer == "mla":
        return attn.init_mla_cache(cfg, batch, max_len, dtype)
    if spec.mixer == "mamba":
        return ssm_mod.init_mamba_state(cfg, batch, dtype)
    if spec.mixer == "mlstm":
        return xlstm_mod.init_mlstm_state(cfg, batch, dtype)
    if spec.mixer == "slstm":
        return xlstm_mod.init_slstm_state(cfg, batch, dtype)
    raise ValueError(spec.mixer)


def block_decode(params, cfg: ModelConfig, spec: LayerSpec, x, cache, pos,
                 mla_absorb: bool = False, moe_dispatch: bool = False):
    """One-token block step. Returns (x, new_cache).  ``moe_dispatch``
    switches decode MoE from per-token expert-weight GATHER (simple but
    all-gathers expert weights over the model axis every step) to the same
    capacity-dispatch path as training (tokens move, weights stay) — the
    §Perf collective-term optimization for MoE decode."""
    if spec.mixer in ("mlstm", "slstm"):
        f = xlstm_mod.mlstm_decode if spec.mixer == "mlstm" else xlstm_mod.slstm_decode
        h, new_cache = f(params["mixer"], cfg, x, cache)
        return x + h, new_cache
    h = rmsnorm(params["norm1"], x, eps=cfg.norm_eps)
    if spec.mixer == "attn":
        h, new_cache = attn.attn_decode(params["mixer"], cfg, spec, h, cache, pos)
    elif spec.mixer == "mla":
        h, new_cache = attn.mla_decode(params["mixer"], cfg, spec, h, cache, pos,
                                       absorb=mla_absorb)
    else:
        h, new_cache = ssm_mod.mamba_decode(params["mixer"], cfg, h, cache)
    x = x + h
    if spec.ffn != "none":
        h = rmsnorm(params["norm2"], x, eps=cfg.norm_eps)
        if spec.ffn == "moe":
            if moe_dispatch:
                h, _ = moe_mod.moe_ffn(params["ffn"], cfg, h)
            else:
                h = moe_mod.moe_decode_ffn(params["ffn"], cfg, h)
        else:
            h = mlp(params["ffn"], h, cfg.activation)
        x = x + h
    return x, new_cache


# ---------------------------------------------------------------------------
# Stack (scan over segments)
# ---------------------------------------------------------------------------

def stack_desc_tree(cfg: ModelConfig, plan: Tuple[Segment, ...]) -> List[Any]:
    """Descriptor tree: list over segments; each segment is a list over period
    positions of block descriptors, stacked over ``repeats`` when > 1."""
    segs = []
    for seg in plan:
        period = [block_desc(cfg, spec) for spec in seg.period]
        if seg.repeats > 1:
            period = [stack_desc(p, seg.repeats) for p in period]
        segs.append(period)
    return segs


def _sqrt_factor(n: int) -> int:
    """Largest divisor of n that is <= sqrt(n)."""
    best = 1
    f = 1
    while f * f <= n:
        if n % f == 0:
            best = f
        f += 1
    return best


def stack_train(params_segs, cfg: ModelConfig, plan, x, positions,
                causal: bool = True, remat: bool = True):
    """``remat=True`` checkpoints each layer period, and long segments use a
    TWO-LEVEL scan (outer x inner ~ sqrt(repeats)) with the inner scan also
    rematerialized, so the backward pass stores O(outer + inner) layer
    inputs instead of O(repeats) — the sqrt-remat policy that keeps the
    95-layer configs inside 16 GB/chip."""
    aux_total = jnp.zeros((), jnp.float32)
    for seg, seg_params in zip(plan, params_segs):
        def period_fn(ps, h, seg=seg):
            h = constrain(h, ("b", None, None))
            a = jnp.zeros((), jnp.float32)
            for spec, p in zip(seg.period, ps):
                def blk(p_, h_, spec=spec):
                    return block_train(p_, cfg, spec, h_, positions, causal)
                if remat and len(seg.period) > 2:
                    # long heterogeneous periods (jamba's 8-layer block,
                    # gemma3's 6): remat per BLOCK too, so the period
                    # backward holds one block's intermediates at a time
                    blk = jax.checkpoint(blk)
                h, aux = blk(p, h)
                a = a + aux
            return h, a

        if remat:
            period_fn = jax.checkpoint(period_fn)

        if seg.repeats == 1:
            x, aux = period_fn(seg_params, x)
            aux_total += aux
            continue

        def body(carry, ps, fn=period_fn):
            h, a = carry
            h, aux = fn(ps, h)
            return (h, a + aux), None

        inner = _sqrt_factor(seg.repeats) if remat else 1
        if inner <= 1:
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), seg_params)
        else:
            outer = seg.repeats // inner
            ps2 = jax.tree.map(
                lambda p: p.reshape((outer, inner) + p.shape[1:]), seg_params)

            @jax.checkpoint
            def inner_scan(carry, ps_in, body=body):
                out, _ = jax.lax.scan(body, carry, ps_in)
                return out

            def outer_body(carry, ps_in, fn=inner_scan):
                return fn(carry, ps_in), None

            (x, aux_total), _ = jax.lax.scan(outer_body, (x, aux_total), ps2)
    return x, aux_total


def stack_prefill(params_segs, cfg: ModelConfig, plan, x, positions,
                  max_len: int):
    """Returns (x, aux_total, cache) where cache mirrors stack_cache()."""
    aux_total = jnp.zeros((), jnp.float32)
    caches = []
    for seg, seg_params in zip(plan, params_segs):
        if seg.repeats == 1:
            seg_caches = []
            for spec, p in zip(seg.period, seg_params):
                x, aux, c = block_prefill(p, cfg, spec, x, positions, max_len)
                aux_total += aux
                seg_caches.append(c)
            caches.append(seg_caches)
        else:
            def body(carry, ps):
                h, a = carry
                cs = []
                for spec, p in zip(seg.period, ps):
                    h, aux, c = block_prefill(p, cfg, spec, h, positions, max_len)
                    a = a + aux
                    cs.append(c)
                return (h, a), cs

            (x, aux_total), cs = jax.lax.scan(body, (x, aux_total), seg_params)
            caches.append(cs)
    return x, aux_total, caches


def stack_cache(cfg: ModelConfig, plan, batch: int, max_len: int, dtype):
    """ShapeDtypeStruct cache pytree mirroring the segment structure."""
    segs = []
    for seg in plan:
        period = [block_cache(cfg, spec, batch, max_len, dtype) for spec in seg.period]
        if seg.repeats > 1:
            period = [jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((seg.repeats,) + s.shape, s.dtype), p)
                for p in period]
        segs.append(period)
    return segs


# Cache leaves with a per-position length dim — the ones the serving
# engine stores in fixed-size pages (attention K/V, MLA latents).  Every
# other leaf (recurrent h/conv/C, xLSTM states and stabilizers) is carried
# whole per serving slot.  Mirrors the name-based layout knowledge of
# Model.input_partition_specs (DESIGN.md §3/§12).
PAGED_CACHE_LEAVES = ("k", "v", "c_kv", "k_rope")


@dataclasses.dataclass(frozen=True)
class CacheLeafMeta:
    """Per-leaf layout label for the paged serving pool (serve/kv_cache):
    ``kind`` is "paged" (length dim at ``batch_axis + 1``, ``length``
    entries) or "state"; ``batch_axis`` is 1 for leaves stacked over a
    segment's repeats, else 0."""
    kind: str
    batch_axis: int
    length: int


def stack_cache_meta(cfg: ModelConfig, plan, batch: int, max_len: int, dtype):
    """A pytree structurally aligned with :func:`stack_cache` whose leaves
    are :class:`CacheLeafMeta` labels — the serving engine's view of which
    cache leaves page over positions and which are per-slot state."""
    def label(stacked):
        def f(path, s):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            bi = 1 if stacked else 0
            if name in PAGED_CACHE_LEAVES:
                return CacheLeafMeta("paged", bi, int(s.shape[1]))
            return CacheLeafMeta("state", bi, 0)
        return f

    segs = []
    for seg in plan:
        period = [jax.tree_util.tree_map_with_path(
            label(seg.repeats > 1),
            block_cache(cfg, spec, batch, max_len, dtype))
            for spec in seg.period]
        segs.append(period)
    return segs


def materialize_cache(cache_specs):
    """Concrete zero-initialized cache (stabilizer entries 'm' get -1e30)."""
    def init_leaf(path, s):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name == "m":
            return jnp.full(s.shape, -1e30, s.dtype)
        return jnp.zeros(s.shape, s.dtype)
    return jax.tree_util.tree_map_with_path(init_leaf, cache_specs)


def stack_decode(params_segs, cfg: ModelConfig, plan, x, cache_segs, pos,
                 mla_absorb: bool = False, moe_dispatch: bool = False):
    """The stacked cache rides the scan CARRY and is updated in place at the
    layer index (``dynamic_update_index_in_dim``), so XLA aliases the cache
    buffer across iterations instead of double-buffering a multi-GiB xs/ys
    pair (critical at decode_32k/long_500k)."""
    new_cache = []
    for seg, seg_params, seg_cache in zip(plan, params_segs, cache_segs):
        if seg.repeats == 1:
            updated = []
            for spec, p, c in zip(seg.period, seg_params, seg_cache):
                x, nc = block_decode(p, cfg, spec, x, c, pos, mla_absorb,
                                     moe_dispatch)
                updated.append(nc)
            new_cache.append(updated)
        else:
            def index_cache(tree, i):
                return jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, False), tree)

            def write_cache(tree, new, i):
                return jax.tree.map(
                    lambda c, nc: jax.lax.dynamic_update_index_in_dim(
                        c, nc.astype(c.dtype), i, 0), tree, new)

            def body(carry, inp, seg=seg):
                h, cache_all = carry
                ps, i = inp
                new_list = []
                for spec, p, c in zip(seg.period, ps,
                                      [index_cache(t, i) for t in cache_all]):
                    h, nc = block_decode(p, cfg, spec, h, c, pos, mla_absorb,
                                         moe_dispatch)
                    new_list.append(nc)
                cache_all = [write_cache(t, nc, i)
                             for t, nc in zip(cache_all, new_list)]
                return (h, cache_all), None

            (x, seg_cache), _ = jax.lax.scan(
                body, (x, list(seg_cache)),
                (seg_params, jnp.arange(seg.repeats)))
            new_cache.append(seg_cache)
    return x, new_cache
