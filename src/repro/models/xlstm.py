"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, exponential gating)
and sLSTM (scalar memory with recurrent gate connections).

Both recurrences use the paper's max-stabilizer (m_t) for the exponential
gates and run as exact sequential ``lax.scan`` over time; decode is the O(1)
single-step update on the carried state.  A chunkwise-parallel mLSTM (MXU
matmuls over chunks) is the documented perf alternative — see EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.scan_utils import chunked_scan
from repro.models.layers import ParamDesc, norm_desc, rmsnorm
from repro.models.sharding_ctx import constrain, constrain_hard

MLSTM_PF = 2          # mLSTM up-projection factor
SLSTM_FF_PF = 4 / 3   # sLSTM post-block gated FFN factor


def _heads(cfg: ModelConfig, d: int) -> Tuple[int, int]:
    H = cfg.num_heads
    return H, d // H


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_desc(cfg: ModelConfig) -> Dict[str, ParamDesc]:
    d = cfg.d_model
    di = MLSTM_PF * d
    return {
        "norm": norm_desc(d),
        "up": ParamDesc((d, 2 * di), ("embed", "inner")),
        "conv_w": ParamDesc((cfg.ssm_conv, di), (None, "inner"), "small"),
        "conv_b": ParamDesc((di,), ("inner",), "zeros"),
        "wq": ParamDesc((di, di), ("inner", "inner")),
        "wk": ParamDesc((di, di), ("inner", "inner")),
        "wv": ParamDesc((di, di), ("inner", "inner")),
        "w_if": ParamDesc((di, 2 * cfg.num_heads), ("inner", None), "small"),
        "b_if": ParamDesc((2 * cfg.num_heads,), (None,), "zeros"),
        "out_norm": norm_desc(di),
        "down": ParamDesc((di, d), ("inner", "embed")),
    }


def _mlstm_pre(params, cfg, x):
    di = MLSTM_PF * cfg.d_model
    H, dh = _heads(cfg, di)
    u = rmsnorm(params["norm"], x, eps=cfg.norm_eps) @ params["up"]
    xm, z = jnp.split(u, 2, axis=-1)
    return xm, z, H, dh


def mlstm_forward(params, cfg: ModelConfig, x, return_state: bool = False):
    """x: (B, T, d)."""
    B, T, d = x.shape
    xm, z, H, dh = _mlstm_pre(params, cfg, x)
    K = params["conv_w"].shape[0]
    padded = jnp.pad(xm, ((0, 0), (K - 1, 0), (0, 0)))
    conv = sum(padded[:, i:i + T, :] * params["conv_w"][i] for i in range(K))
    conv = jax.nn.silu(conv + params["conv_b"])

    q = (conv @ params["wq"]).reshape(B, T, H, dh)
    k = (conv @ params["wk"]).reshape(B, T, H, dh) / jnp.sqrt(jnp.asarray(dh, x.dtype))
    v = (xm @ params["wv"]).reshape(B, T, H, dh)
    gates = conv @ params["w_if"] + params["b_if"]          # (B, T, 2H)
    log_i, f_raw = jnp.split(gates.astype(jnp.float32), 2, axis=-1)
    log_f = -jax.nn.softplus(-f_raw)                         # log sigmoid(f)

    out_dtype = x.dtype

    def step(carry, inp):
        C, n, m = carry                                      # (B,H,dh,dh),(B,H,dh),(B,H)
        q_t, k_t, v_t, li_t, lf_t = inp
        q_t, k_t, v_t = (t.astype(jnp.float32) for t in (q_t, k_t, v_t))
        m_new = jnp.maximum(lf_t + m, li_t)
        i_p = jnp.exp(li_t - m_new)
        f_p = jnp.exp(lf_t + m - m_new)
        C = C * f_p[..., None, None] + i_p[..., None, None] * (
            v_t[..., :, None] * k_t[..., None, :])
        n = n * f_p[..., None] + i_p[..., None] * k_t
        num = jnp.einsum("bhvk,bhk->bhv", C, q_t)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t)), 1.0)
        h = num / den[..., None]
        return (C, n, m_new), h.astype(out_dtype)

    init = (constrain_hard(jnp.zeros((B, H, dh, dh), jnp.float32), ("b", None, None, None)),
            constrain_hard(jnp.zeros((B, H, dh), jnp.float32), ("b", None, None)),
            constrain_hard(jnp.full((B, H), -1e30, jnp.float32), ("b", None)))
    if cfg.mlstm_parallel and T % cfg.mlstm_chunk == 0:
        hs_btHd, final = mlstm_chunkwise(q, k, v, log_i, log_f, init,
                                         chunk=cfg.mlstm_chunk)
        h = hs_btHd.astype(out_dtype).reshape(B, T, H * dh)
    else:
        c4 = lambda a: constrain(a, (None, "b", None, None))
        # qkv stacks stay bf16 in HBM (halves the scan-input footprint); the
        # step body upcasts before touching the f32 matrix state.
        xs = (c4(q.transpose(1, 0, 2, 3)),
              c4(k.transpose(1, 0, 2, 3)),
              c4(v.transpose(1, 0, 2, 3)),
              constrain(log_i.transpose(1, 0, 2), (None, "b", None)),
              constrain(log_f.transpose(1, 0, 2), (None, "b", None)))
        final, hs = chunked_scan(step, init, xs, chunk=cfg.mlstm_chunk)
        h = constrain(hs, (None, "b", None, None)).transpose(1, 0, 2, 3).reshape(B, T, H * dh)
    h = rmsnorm(params["out_norm"], h, eps=cfg.norm_eps)
    h = h * jax.nn.silu(z)
    out = h @ params["down"]
    if return_state:
        C, n, m = final
        K = params["conv_w"].shape[0]
        tail = jnp.pad(xm, ((0, 0), (max(0, K - 1 - T), 0), (0, 0)))[:, -(K - 1):, :]
        return out, {"C": C, "n": n, "m": m, "conv": tail}
    return out


def mlstm_chunkwise(q, k, v, log_i, log_f, init, chunk: int):
    """Chunkwise-PARALLEL mLSTM recurrence (the xLSTM appendix / GLA form).

    Replaces the per-step scan with, per chunk of length c: one (c, c)
    masked score matmul + one (c, dh) value matmul intra-chunk, plus an
    inter-chunk contribution from the carried matrix state — MXU work
    instead of 4096 sequential outer products, with exact exponential-gating
    stabilization carried in ``m``.  Verified equivalent to the sequential
    step in tests/test_xlstm_chunkwise.py.

    q, k, v: (B, T, H, dh) (k pre-scaled by 1/sqrt(dh));
    log_i, log_f: (B, T, H) f32.  Returns (hs (B, T, H, dh) f32, final
    (C, n, m) state).
    """
    B, T, H, dh = q.shape
    assert T % chunk == 0, (T, chunk)
    nc, c = T // chunk, chunk
    resh = lambda x: x.reshape(B, nc, c, *x.shape[2:]).swapaxes(0, 1)
    qc, kc, vc = (resh(q.astype(jnp.float32)), resh(k.astype(jnp.float32)),
                  resh(v.astype(jnp.float32)))
    lic, lfc = resh(log_i), resh(log_f)              # (nc, B, c, H)

    tri = jnp.tril(jnp.ones((c, c), bool))           # s <= t

    def chunk_step(carry, inp):
        C_prev, n_prev, m_prev = carry               # (B,H,dh,dh),(B,H,dh),(B,H)
        qt, kt, vt, li, lf = inp                     # (B,c,H,dh)/(B,c,H)
        a = jnp.cumsum(lf, axis=1)                   # (B,c,H) cumulative log-forget
        a_tot = a[:, -1]                             # (B,H)
        # log-weight of source s seen from target t: a_t - a_s + li_s
        lw = a[:, :, None, :] - a[:, None, :, :] + li[:, None, :, :]
        lw = jnp.where(tri[None, :, :, None], lw, -jnp.inf)          # (B,t,s,H)
        m_intra = jnp.max(lw, axis=2)                                # (B,c,H)
        m_t = jnp.maximum(a + m_prev[:, None, :], m_intra)           # (B,c,H)
        w = jnp.exp(lw - m_t[:, :, None, :])                         # (B,t,s,H)
        e_inter = jnp.exp(a + m_prev[:, None, :] - m_t)              # (B,c,H)

        s_qk = jnp.einsum("bthd,bshd->btsh", qt, kt)                 # (B,t,s,H)
        num = (e_inter[..., None] * jnp.einsum("bhvk,bthk->bthv", C_prev, qt)
               + jnp.einsum("btsh,bshv->bthv", w * s_qk, vt))
        den = (e_inter * jnp.einsum("bhk,bthk->bth", n_prev, qt)
               + jnp.einsum("btsh,btsh->bth", w, s_qk))
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]          # (B,c,H,dh)

        # chunk-end state
        lw_end = a_tot[:, None, :] - a + li                          # (B,s,H)
        m_new = jnp.maximum(a_tot + m_prev, jnp.max(lw_end, axis=1))
        decay = jnp.exp(a_tot + m_prev - m_new)                      # (B,H)
        src = jnp.exp(lw_end - m_new[:, None, :])                    # (B,s,H)
        C_new = (decay[:, :, None, None] * C_prev
                 + jnp.einsum("bsh,bshv,bshk->bhvk", src, vt, kt))
        n_new = decay[..., None] * n_prev + jnp.einsum("bsh,bshk->bhk", src, kt)
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(chunk_step, init, (qc, kc, vc, lic, lfc))
    hs = hs.swapaxes(0, 1).reshape(B, T, H, dh)
    return hs, (C, n, m)


def init_mlstm_state(cfg: ModelConfig, batch: int, dtype):
    di = MLSTM_PF * cfg.d_model
    H, dh = _heads(cfg, di)
    K = cfg.ssm_conv
    return {"C": jax.ShapeDtypeStruct((batch, H, dh, dh), jnp.float32),
            "n": jax.ShapeDtypeStruct((batch, H, dh), jnp.float32),
            "m": jax.ShapeDtypeStruct((batch, H), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, K - 1, di), dtype)}


def mlstm_decode(params, cfg: ModelConfig, x, state):
    """x: (B, 1, d)."""
    B = x.shape[0]
    xm, z, H, dh = _mlstm_pre(params, cfg, x)
    xm, z = xm[:, 0], z[:, 0]
    window = jnp.concatenate([state["conv"], xm[:, None, :]], axis=1)
    conv = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, params["conv_w"]) + params["conv_b"])
    q = (conv @ params["wq"]).reshape(B, H, dh).astype(jnp.float32)
    k = ((conv @ params["wk"]).reshape(B, H, dh) /
         jnp.sqrt(jnp.asarray(dh, x.dtype))).astype(jnp.float32)
    v = (xm @ params["wv"]).reshape(B, H, dh).astype(jnp.float32)
    gates = (conv @ params["w_if"] + params["b_if"]).astype(jnp.float32)
    log_i, f_raw = jnp.split(gates, 2, axis=-1)
    log_f = -jax.nn.softplus(-f_raw)
    m_new = jnp.maximum(log_f + state["m"], log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + state["m"] - m_new)
    C = state["C"] * f_p[..., None, None] + i_p[..., None, None] * (
        v[..., :, None] * k[..., None, :])
    n = state["n"] * f_p[..., None] + i_p[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h = (num / den[..., None]).reshape(B, H * dh).astype(x.dtype)
    h = rmsnorm(params["out_norm"], h, eps=cfg.norm_eps) * jax.nn.silu(z)
    out = (h @ params["down"])[:, None, :]
    return out, {"C": C, "n": n, "m": m_new, "conv": window[:, 1:, :]}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_desc(cfg: ModelConfig) -> Dict[str, ParamDesc]:
    d = cfg.d_model
    H, dh = _heads(cfg, d)
    ff = int(round(SLSTM_FF_PF * d / 64) * 64)
    return {
        "norm": norm_desc(d),
        "w_in": ParamDesc((d, 4 * d), ("embed", "inner")),       # i,f,z,o pre-acts
        "r": ParamDesc((H, dh, 4 * dh), (None, None, None), "small"),  # block-diag recurrent
        "b": ParamDesc((4 * d,), (None,), "zeros"),
        "out_norm": norm_desc(d),
        "up": ParamDesc((d, 2 * ff), ("embed", "ffn")),
        "down": ParamDesc((ff, d), ("ffn", "embed")),
    }


def _slstm_cell(params, cfg, x_proj_t, carry):
    """One sLSTM time step.  x_proj_t: (B, 4d) pre-activations from W x_t."""
    c, n, m, h = carry                                   # each (B, H, dh)
    B = x_proj_t.shape[0]
    d = cfg.d_model
    H, dh = _heads(cfg, d)
    rec = jnp.einsum("bhd,hdk->bhk", h, params["r"].astype(jnp.float32))  # (B,H,4dh)
    pre = x_proj_t.reshape(B, H, 4 * dh).astype(jnp.float32) + rec + \
        params["b"].reshape(H, 4 * dh).astype(jnp.float32)
    i_raw, f_raw, z_raw, o_raw = jnp.split(pre, 4, axis=-1)
    log_i = i_raw
    log_f = -jax.nn.softplus(-f_raw)                     # sigmoid-form forget gate
    m_new = jnp.maximum(log_f + m, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(z_raw)
    o = jax.nn.sigmoid(o_raw)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new)


def slstm_forward(params, cfg: ModelConfig, x, return_state: bool = False):
    B, T, d = x.shape
    H, dh = _heads(cfg, d)
    u = rmsnorm(params["norm"], x, eps=cfg.norm_eps)
    x_proj = u @ params["w_in"]                          # (B, T, 4d)
    out_dtype = x.dtype

    def step(carry, xp_t):
        new = _slstm_cell(params, cfg, xp_t, carry)
        return new, new[3].astype(out_dtype)

    zeros = constrain_hard(jnp.zeros((B, H, dh), jnp.float32), ("b", None, None))
    init = (zeros, zeros, constrain_hard(jnp.full((B, H, dh), -1e30, jnp.float32), ("b", None, None)), zeros)
    xp = constrain(x_proj.transpose(1, 0, 2), (None, "b", "m"))
    final, hs = chunked_scan(step, init, xp, chunk=cfg.mlstm_chunk)
    h = constrain(hs, (None, "b", None, None)).transpose(1, 0, 2, 3).reshape(B, T, d)
    h = rmsnorm(params["out_norm"], h, eps=cfg.norm_eps)
    gate, up = jnp.split(h @ params["up"], 2, axis=-1)
    out = (jax.nn.gelu(gate) * up) @ params["down"]
    if return_state:
        c, n, m, hf = final
        return out, {"c": c, "n": n, "m": m, "h": hf}
    return out


def init_slstm_state(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    H, dh = _heads(cfg, d)
    s = jax.ShapeDtypeStruct((batch, H, dh), jnp.float32)
    return {"c": s, "n": s, "m": s, "h": s}


def slstm_decode(params, cfg: ModelConfig, x, state):
    B = x.shape[0]
    u = rmsnorm(params["norm"], x[:, 0], eps=cfg.norm_eps)
    xp = u @ params["w_in"]
    carry = (state["c"], state["n"], state["m"], state["h"])
    c, n, m, h = _slstm_cell(params, cfg, xp, carry)
    d = cfg.d_model
    hv = h.reshape(B, d).astype(x.dtype)
    hv = rmsnorm(params["out_norm"], hv, eps=cfg.norm_eps)
    gate, up = jnp.split(hv @ params["up"], 2, axis=-1)
    out = ((jax.nn.gelu(gate) * up) @ params["down"])[:, None, :]
    return out, {"c": c, "n": n, "m": m, "h": h}
