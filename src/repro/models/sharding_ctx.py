"""Activation-sharding context.

XLA's sharding propagation loses the batch dimension through the
transpose/reshape-heavy recurrent scans (it then replicates multi-GB
intermediates on every device — observed as all-gathers of the global
batch in the xLSTM dry-run).  Model code therefore pins activations with
``constrain(x, dims)`` at block boundaries and around time-scans.

The context is process-global and set by the launcher (dryrun/train/serve)
before tracing; when unset (CPU unit tests), constraints are no-ops.
``dims`` marks each tensor dim as one of:

  'b'  — batch          -> the data axes ('pod','data')
  'm'  — model-parallel -> 'model'
  None — unsharded
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX = {"mesh": None, "batch_axes": None, "model_axis": None, "manual": False,
        "manual_axes": (), "tp_axis": None}


@contextlib.contextmanager
def tp_region(axis: Optional[str]):
    """Declare a MANUAL tensor-parallel shard_map axis for the duration of
    tracing: transformer dense-FFN blocks switch from ``layers.mlp`` to the
    explicit Megatron wire ``layers.mlp_tp`` over ``axis`` (DESIGN.md §14).
    This is the manual-collectives sibling of ``model_axis`` (which lets
    XLA's partitioner insert the TP collectives): inside a shard_map whose
    manual axes include ``axis``, the activation reductions go through
    ``collectives.api`` and are OURS to schedule and price."""
    old = _CTX["tp_axis"]
    _CTX["tp_axis"] = axis
    try:
        yield
    finally:
        _CTX["tp_axis"] = old


def tp_axis() -> Optional[str]:
    """The active manual tp axis name, or None."""
    return _CTX["tp_axis"]


@contextlib.contextmanager
def manual_region(axes: Sequence[str] = ()):
    """Inside a shard_map whose manual axes include the data axes, sharding
    constraints must not name them (and WSC on auto axes under shard_map is
    buggy in this JAX) — so all constraints become no-ops while tracing the
    manual body.  ``axes`` names the shard_map's manual axes so
    :func:`host_callback_safe` can tell full-manual bodies (host callbacks
    fine) from partial-manual ones (XLA aborts on them — see compat)."""
    old = _CTX["manual"], _CTX["manual_axes"]
    _CTX["manual"] = True
    _CTX["manual_axes"] = tuple(axes)
    try:
        yield
    finally:
        _CTX["manual"], _CTX["manual_axes"] = old


def host_callback_safe() -> bool:
    """Whether a host callback (``jax.debug.callback``) may be baked into
    the program being traced.  False exactly in a PARTIAL-manual shard_map
    body: manual over some mesh axes while another live (size>1) axis
    stays auto — XLA's partitioner aborts on the callback custom-call
    there (hlo_sharding.cc ``!IsManual()``).  Full-manual bodies and
    ordinary pjit programs are safe."""
    mesh = _CTX["mesh"]
    if not _CTX["manual"] or mesh is None:
        return True
    manual = set(_CTX["manual_axes"])
    return all(a in manual or mesh.shape[a] == 1 for a in mesh.axis_names)


def set_mesh_ctx(mesh, batch_axes: Sequence[str], model_axis: Optional[str] = "model"):
    _CTX["mesh"] = mesh
    _CTX["batch_axes"] = tuple(batch_axes)
    _CTX["model_axis"] = model_axis if (model_axis in getattr(mesh, "axis_names", ())) else None


def clear_mesh_ctx():
    _CTX["mesh"] = None
    _CTX["batch_axes"] = None
    _CTX["model_axis"] = None


@contextlib.contextmanager
def mesh_ctx(mesh, batch_axes: Sequence[str], model_axis: Optional[str] = "model"):
    old = dict(_CTX)
    set_mesh_ctx(mesh, batch_axes, model_axis)
    try:
        yield
    finally:
        _CTX.update(old)


def num_batch_shards() -> int:
    """Size of the data axes in the active context (1 when unset) — used by
    the MoE layer to group its dispatch per data shard (expert-parallel
    per-rank capacity semantics)."""
    mesh = _CTX["mesh"]
    if mesh is None or not _CTX["batch_axes"] or _CTX["manual"]:
        return 1  # inside a manual region the body already IS one shard
    n = 1
    for a in _CTX["batch_axes"]:
        n *= mesh.shape[a]
    return n


def constrain_hard(x, dims: Sequence[Optional[str]]):
    """Like constrain, but un-pinned dims are HARD-replicated (None), not
    UNCONSTRAINED.  Use inside recurrent time scans: without the hard pin,
    the SPMD partitioner may shard the small carried state over 'model' and
    emit an all-reduce PER TIME STEP (found in the xlstm §Perf iteration)."""
    mesh = _CTX["mesh"]
    if mesh is None or _CTX["manual"] or x.ndim != len(dims):
        return x
    spec = []
    for i, d in enumerate(dims):
        if d == "b" and _CTX["batch_axes"]:
            size = 1
            for a in _CTX["batch_axes"]:
                size *= mesh.shape[a]
            ok = x.shape[i] % size == 0 and x.shape[i] >= size
            spec.append(_CTX["batch_axes"] if ok else None)
        elif d == "m" and _CTX["model_axis"]:
            size = mesh.shape[_CTX["model_axis"]]
            ok = x.shape[i] % size == 0 and x.shape[i] >= size
            spec.append(_CTX["model_axis"] if ok else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain(x, dims: Sequence[Optional[str]]):
    """Pin sharding of ``x``: dims[i] in {'b', 'm', None} per dimension.
    No-op when no mesh context is set or a dim is not divisible."""
    mesh = _CTX["mesh"]
    if mesh is None or _CTX["manual"] or x.ndim != len(dims):
        return x
    # Dims we don't explicitly pin stay UNCONSTRAINED: a None entry in a
    # with_sharding_constraint spec is a HARD replication constraint, which
    # forces XLA to all-gather naturally-sharded values (e.g. kv=8 heads on
    # a 16-way model axis) — the dominant collective-churn bug found in the
    # §Perf iterations.
    U = P.UNCONSTRAINED
    spec = []
    pinned = 0
    for i, d in enumerate(dims):
        if d == "b" and _CTX["batch_axes"]:
            size = 1
            for a in _CTX["batch_axes"]:
                size *= mesh.shape[a]
            ok = x.shape[i] % size == 0 and x.shape[i] >= size
            spec.append(_CTX["batch_axes"] if ok else U)
            pinned += ok
        elif d == "m" and _CTX["model_axis"]:
            size = mesh.shape[_CTX["model_axis"]]
            ok = x.shape[i] % size == 0 and x.shape[i] >= size
            spec.append(_CTX["model_axis"] if ok else U)
            pinned += ok
        else:
            spec.append(U)
    if not pinned:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
