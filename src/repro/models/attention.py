"""Attention: GQA/MQA with RoPE, sliding windows, logit softcap, QK-norm,
DeepSeek-V2 MLA (latent KV), and single-token KV-cache decoding.

Full-sequence attention is computed in a chunked, flash-style streaming form
(``lax.scan`` over query and key blocks with a running softmax) so that the
32k prefill shapes never materialize a (T, T) score matrix.  The Pallas TPU
kernel in ``repro.kernels.flash_attention`` implements the same schedule with
explicit VMEM BlockSpecs; this module is its pure-jnp twin and the fallback
used on CPU and in dry-runs.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig
from repro.models.layers import ParamDesc, apply_rope, norm_desc, rmsnorm
from repro.models.sharding_ctx import constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Chunked (flash-style) full-sequence attention
# ---------------------------------------------------------------------------

def _block_mask(q_pos, k_pos, window):
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m


def _masked_scores(qc, kc, qp, kp, scale, softcap, causal, window):
    """(B,KV,G,cq,hd) x (B,KV,ck,hd) -> capped+masked scores (f32)."""
    s = jnp.einsum("bkgqh,bkch->bkgqc", qc, kc,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    if causal:
        mask = _block_mask(qp, kp, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    elif window is not None:
        mask = jnp.abs(qp[:, None] - kp[None, :]) < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    return s


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(qg, kg, vg, causal, window, softcap, q_chunk, kv_chunk, q_offset):
    out, _ = _flash_fwd_impl(qg, kg, vg, causal, window, softcap,
                             q_chunk, kv_chunk, q_offset)
    return out


def _flash_fwd_impl(qg, kg, vg, causal, window, softcap, q_chunk, kv_chunk,
                    q_offset):
    """qg: (B,KV,G,T,hd); kg/vg: (B,KV,S,hd). Returns (out, lse)."""
    B, KV, G, T, hd = qg.shape
    S = kg.shape[2]
    scale = 1.0 / np.sqrt(hd)
    nq, nk = T // q_chunk, S // kv_chunk
    q_positions = q_offset + jnp.arange(T)
    k_positions = jnp.arange(S)

    def q_step(_, qi):
        qc = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=3)
        qp = jax.lax.dynamic_slice_in_dim(q_positions, qi * q_chunk, q_chunk, 0)

        def kv_step(carry, ki):
            m_prev, l_prev, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(kg, ki * kv_chunk, kv_chunk, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(vg, ki * kv_chunk, kv_chunk, axis=2)
            kp = jax.lax.dynamic_slice_in_dim(k_positions, ki * kv_chunk, kv_chunk, 0)
            s = _masked_scores(qc, kc, qp, kp, scale, softcap, causal, window)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkch->bkgqh", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        init = (jnp.full((B, KV, G, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, q_chunk), jnp.float32),
                jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out.astype(qg.dtype), lse)

    _, (chunks, lses) = jax.lax.scan(q_step, None, jnp.arange(nq))
    # chunks: (nq, B, KV, G, cq, hd) -> (B, KV, G, T, hd)
    out = jnp.moveaxis(chunks, 0, 3).reshape(B, KV, G, T, hd)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, KV, G, T)
    return out, lse


def _flash_fwd(qg, kg, vg, causal, window, softcap, q_chunk, kv_chunk, q_offset):
    out, lse = _flash_fwd_impl(qg, kg, vg, causal, window, softcap,
                               q_chunk, kv_chunk, q_offset)
    return out, (qg, kg, vg, out, lse)


def _flash_bwd(causal, window, softcap, q_chunk, kv_chunk, q_offset,
               res, do):
    """FlashAttention-2 style backward: recompute P per (q, kv) block from
    the saved log-sum-exp; memory is O(block), not O(T^2) and no per-step
    probability residuals are stored."""
    qg, kg, vg, out, lse = res
    B, KV, G, T, hd = qg.shape
    S = kg.shape[2]
    scale = 1.0 / np.sqrt(hd)
    nq, nk = T // q_chunk, S // kv_chunk
    q_positions = q_offset + jnp.arange(T)
    k_positions = jnp.arange(S)
    do = do.astype(jnp.float32)
    delta = jnp.sum(do * out.astype(jnp.float32), axis=-1)   # (B,KV,G,T)

    def kv_step(carry, ki):
        dq = carry
        kc = jax.lax.dynamic_slice_in_dim(kg, ki * kv_chunk, kv_chunk, axis=2)
        vc = jax.lax.dynamic_slice_in_dim(vg, ki * kv_chunk, kv_chunk, axis=2)
        kp = jax.lax.dynamic_slice_in_dim(k_positions, ki * kv_chunk, kv_chunk, 0)

        def q_step(carry_q, qi):
            dk, dv = carry_q
            qc = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=3)
            qp = jax.lax.dynamic_slice_in_dim(q_positions, qi * q_chunk, q_chunk, 0)
            lse_c = jax.lax.dynamic_slice_in_dim(lse, qi * q_chunk, q_chunk, axis=3)
            do_c = jax.lax.dynamic_slice_in_dim(do, qi * q_chunk, q_chunk, axis=3)
            dl_c = jax.lax.dynamic_slice_in_dim(delta, qi * q_chunk, q_chunk, axis=3)
            s_raw = jnp.einsum("bkgqh,bkch->bkgqc", qc, kc,
                               preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                t = jnp.tanh(s_raw / softcap)
                s = softcap * t
            else:
                s = s_raw
            if causal:
                mask = _block_mask(qp, kp, window)[None, None, None]
            elif window is not None:
                mask = (jnp.abs(qp[:, None] - kp[None, :]) < window)[None, None, None]
            else:
                mask = jnp.ones(s.shape[-2:], jnp.bool_)[None, None, None]
            p = jnp.where(mask, jnp.exp(s - lse_c[..., None]), 0.0)
            dv = dv + jnp.einsum("bkgqc,bkgqh->bkch", p, do_c)
            dp = jnp.einsum("bkgqh,bkch->bkgqc", do_c, vc.astype(jnp.float32))
            ds = p * (dp - dl_c[..., None])
            if softcap is not None:
                ds = ds * (1.0 - t * t)
            ds = ds * scale
            dq_c = jnp.einsum("bkgqc,bkch->bkgqh", ds, kc.astype(jnp.float32))
            dk = dk + jnp.einsum("bkgqc,bkgqh->bkch", ds, qc.astype(jnp.float32))
            return (dk, dv), dq_c

        init = (jnp.zeros((B, KV, kv_chunk, hd), jnp.float32),
                jnp.zeros((B, KV, kv_chunk, hd), jnp.float32))
        (dk, dv), dq_chunks = jax.lax.scan(q_step, init, jnp.arange(nq))
        dq_new = jnp.moveaxis(dq_chunks, 0, 3).reshape(B, KV, G, T, hd)
        return dq + dq_new, (dk, dv)

    dq0 = jnp.zeros((B, KV, G, T, hd), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, jnp.arange(nk))
    dk = jnp.moveaxis(dks, 0, 2).reshape(B, KV, S, hd)
    dv = jnp.moveaxis(dvs, 0, 2).reshape(B, KV, S, hd)
    return dq.astype(qg.dtype), dk.astype(kg.dtype), dv.astype(vg.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, q_chunk: int = 512,
                    kv_chunk: int = 1024, q_offset: int = 0):
    """q: (B, T, H, hd); k, v: (B, S, KV, hd) with H = KV * G.

    Returns (B, T, H, hd).  Streaming softmax over (q, kv) blocks — the
    score matrix is never materialized — with a FlashAttention-2 custom VJP
    (backward recomputes probabilities per block from the saved LSE, so
    training memory is O(T·hd) instead of O(T·S)).  ``q_offset`` is the
    absolute position of q[0].
    """
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, T)
    kv_chunk = min(kv_chunk, S)
    assert T % q_chunk == 0 and S % kv_chunk == 0, (T, S, q_chunk, kv_chunk)

    # (B, KV, G, T, hd) so grouped heads broadcast against (B, KV, S, hd)
    qg = constrain(q.reshape(B, T, KV, G, hd).transpose(0, 2, 3, 1, 4),
                   ("b", "m", None, None, None))
    kg = constrain(k.transpose(0, 2, 1, 3), ("b", "m", None, None))
    vg = constrain(v.transpose(0, 2, 1, 3), ("b", "m", None, None))
    out = _flash(qg, kg, vg, causal, window, softcap, q_chunk, kv_chunk,
                 q_offset)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, T, H, hd)


def attention_reference(q, k, v, *, causal=True, window=None, softcap=None,
                        q_offset: int = 0):
    """Naive O(T^2)-memory oracle (tests only)."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, hd)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, k,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qp = q_offset + jnp.arange(T)
    kp = jnp.arange(S)
    if causal:
        s = jnp.where(_block_mask(qp, kp, window)[None, None, None], s, NEG_INF)
    elif window is not None:
        m = jnp.abs(qp[:, None] - kp[None, :]) < window
        s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", p, v)
    return out.reshape(B, T, H, hd)


# ---------------------------------------------------------------------------
# Standard GQA attention layer
# ---------------------------------------------------------------------------

def attn_desc(cfg: ModelConfig) -> Dict[str, ParamDesc]:
    d, hd = cfg.d_model, cfg.hd
    desc = {
        "wq": ParamDesc((d, cfg.num_heads * hd), ("embed", "heads")),
        "wk": ParamDesc((d, cfg.num_kv_heads * hd), ("embed", "kv")),
        "wv": ParamDesc((d, cfg.num_kv_heads * hd), ("embed", "kv")),
        "wo": ParamDesc((cfg.num_heads * hd, d), ("heads", "embed")),
    }
    if cfg.qk_norm:
        desc["q_norm"] = norm_desc(hd)
        desc["k_norm"] = norm_desc(hd)
    return desc


def _project_qkv(params, cfg: ModelConfig, x, positions):
    B, T, _ = x.shape
    hd = cfg.hd
    q = (x @ params["wq"]).reshape(B, T, cfg.num_heads, hd)
    k = (x @ params["wk"]).reshape(B, T, cfg.num_kv_heads, hd)
    v = (x @ params["wv"]).reshape(B, T, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, eps=cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, eps=cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_forward(params, cfg: ModelConfig, spec: LayerSpec, x, positions):
    """Full-sequence causal attention (train / prefill). x: (B, T, d)."""
    B, T, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    out = flash_attention(q, k, v, causal=True, window=spec.window,
                          softcap=cfg.attn_logit_softcap)
    return out.reshape(B, T, -1) @ params["wo"]


def attn_prefill(params, cfg: ModelConfig, spec: LayerSpec, x, positions,
                 max_len: int):
    """Full-sequence attention that also emits the decode cache.

    Full-attention layers cache all T entries (padded to ``max_len``);
    sliding-window layers keep a ring buffer of the last ``window`` entries,
    rolled so that entry for position p sits at slot p % window.
    """
    B, T, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, positions)
    out = flash_attention(q, k, v, causal=True, window=spec.window,
                          softcap=cfg.attn_logit_softcap)
    out = out.reshape(B, T, -1) @ params["wo"]

    def to_cache(arr):
        if spec.window and spec.window < max_len:
            W = min(spec.window, T)
            tail = arr[:, T - W:]
            if T > W:
                tail = jnp.roll(tail, shift=(T - W) % W, axis=1)
            L = min(spec.window, max_len)
            return jnp.pad(tail, ((0, 0), (0, L - W), (0, 0), (0, 0)))
        return jnp.pad(arr, ((0, 0), (0, max_len - T), (0, 0), (0, 0)))

    return out, {"k": to_cache(k), "v": to_cache(v)}


def init_attn_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int,
                    dtype) -> Dict[str, jax.ShapeDtypeStruct]:
    """Cache shapes for one attention layer.  Sliding-window layers keep a
    ring buffer of ``window`` entries instead of the full context."""
    L = min(max_len, spec.window) if spec.window else max_len
    shape = (batch, L, cfg.num_kv_heads, cfg.hd)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def attn_decode(params, cfg: ModelConfig, spec: LayerSpec, x, cache, pos):
    """One-token decode.  x: (B, 1, d); cache: {'k','v'} (B, L, KV, hd);
    pos: scalar int32 — number of tokens already in the cache — or an (B,)
    int32 vector of PER-ROW positions (the serving engine's continuous
    batch, where every slot sits at its own depth; DESIGN.md §12).  The
    scalar path is unchanged; the vector path stores per row via a one-hot
    ``where`` write (bit-identical values to the per-row dynamic slice)."""
    B = x.shape[0]
    hd = cfg.hd
    pos = jnp.asarray(pos, jnp.int32)
    vec = pos.ndim == 1
    positions = pos[:, None] if vec else jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _project_qkv(params, cfg, x, positions)
    L = cache["k"].shape[1]
    slot = pos % L if spec.window else pos
    if vec:
        k_cache = _store_rows(cache["k"], k, slot)
        v_cache = _store_rows(cache["v"], v, slot)
    else:
        k_cache = _dynamic_store(cache["k"], k, slot)
        v_cache = _dynamic_store(cache["v"], v, slot)

    # positions actually stored in each cache slot (ring-aware).  ``p_row``
    # broadcasts the per-row/scalar cases through one set of formulas:
    # valid is (B, L) on the vector path, (L,) on the scalar path.
    idx = jnp.arange(L)
    p_row = pos[:, None] if vec else pos
    if spec.window:
        # slot i holds position p with p % L == i and p <= pos; invalid if p > pos
        # or evicted (pos - p >= window).
        base = p_row - (p_row % L)
        cand = jnp.where(idx <= (p_row % L), base + idx, base - L + idx)
        valid = (cand >= 0) & (cand <= p_row) & ((p_row - cand) < spec.window)
    else:
        valid = idx <= p_row
    vmask = (valid[:, None, None, None, :] if vec
             else valid[None, None, None, None, :])

    qg = q.reshape(B, 1, cfg.num_kv_heads, -1, hd)
    s = jnp.einsum("btkgh,blkh->bkgtl", qg, k_cache,
                   preferred_element_type=jnp.float32) / np.sqrt(hd)
    if cfg.attn_logit_softcap is not None:
        s = cfg.attn_logit_softcap * jnp.tanh(s / cfg.attn_logit_softcap)
    s = jnp.where(vmask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgtl,blkh->btkgh", p, v_cache).reshape(B, 1, -1)
    return out @ params["wo"], {"k": k_cache, "v": v_cache}


def _dynamic_store(cache, new, slot):
    return jax.lax.dynamic_update_slice_in_dim(
        cache, new.astype(cache.dtype), slot, axis=1)


def _store_rows(cache, new, slot):
    """Per-row store: new[b, 0] lands at cache[b, slot[b]] — the
    vector-``pos`` twin of :func:`_dynamic_store`.  cache: (B, L, ...);
    new: (B, 1, ...); slot: (B,) int32."""
    L = cache.shape[1]
    hit = jnp.arange(L)[None, :] == slot[:, None]             # (B, L)
    hit = hit.reshape(hit.shape + (1,) * (cache.ndim - 2))
    return jnp.where(hit, new.astype(cache.dtype), cache)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_desc(cfg: ModelConfig) -> Dict[str, ParamDesc]:
    d, H = cfg.d_model, cfg.num_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq": ParamDesc((d, H * qk), ("embed", "heads")),
        "w_dkv": ParamDesc((d, cfg.kv_lora_rank + cfg.qk_rope_dim), ("embed", "lora")),
        "kv_norm": norm_desc(cfg.kv_lora_rank),
        "w_ukv": ParamDesc((cfg.kv_lora_rank,
                            H * (cfg.qk_nope_dim + cfg.v_head_dim)), ("lora", "heads")),
        "wo": ParamDesc((H * cfg.v_head_dim, d), ("heads", "embed")),
    }


def _mla_qkv(params, cfg: ModelConfig, x, positions):
    B, T, _ = x.shape
    H = cfg.num_heads
    nope, rope, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = (x @ params["wq"]).reshape(B, T, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    latent = x @ params["w_dkv"]
    c_kv = rmsnorm(params["kv_norm"], latent[..., :cfg.kv_lora_rank], eps=cfg.norm_eps)
    k_rope = apply_rope(latent[..., None, cfg.kv_lora_rank:], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand_kv(params, cfg: ModelConfig, c_kv):
    """Up-project latents to per-head K_nope and V."""
    B, L, _ = c_kv.shape
    H, nope, vdim = cfg.num_heads, cfg.qk_nope_dim, cfg.v_head_dim
    kv = (c_kv @ params["w_ukv"]).reshape(B, L, H, nope + vdim)
    return kv[..., :nope], kv[..., nope:]


def mla_forward(params, cfg: ModelConfig, spec: LayerSpec, x, positions):
    B, T, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, positions)
    k_nope, v = _mla_expand_kv(params, cfg, c_kv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, T, cfg.num_heads, cfg.qk_rope_dim))], axis=-1)
    # pad V to q/k head_dim so the shared flash kernel applies, then crop
    pad = q.shape[-1] - cfg.v_head_dim
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    out = flash_attention(q, k, v_p, causal=True)[..., :cfg.v_head_dim]
    return out.reshape(B, T, -1) @ params["wo"]


def mla_prefill(params, cfg: ModelConfig, spec: LayerSpec, x, positions,
                max_len: int):
    B, T, _ = x.shape
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, positions)
    k_nope, v = _mla_expand_kv(params, cfg, c_kv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, T, cfg.num_heads, cfg.qk_rope_dim))], axis=-1)
    pad = q.shape[-1] - cfg.v_head_dim
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    out = flash_attention(q, k, v_p, causal=True)[..., :cfg.v_head_dim]
    out = out.reshape(B, T, -1) @ params["wo"]
    cache = {
        "c_kv": jnp.pad(c_kv, ((0, 0), (0, max_len - T), (0, 0))),
        "k_rope": jnp.pad(k_rope, ((0, 0), (0, max_len - T), (0, 0), (0, 0))),
    }
    return out, cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    return {"c_kv": jax.ShapeDtypeStruct((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jax.ShapeDtypeStruct((batch, max_len, 1, cfg.qk_rope_dim), dtype)}


def mla_decode(params, cfg: ModelConfig, spec: LayerSpec, x, cache, pos,
               absorb: bool = False):
    """One-token MLA decode against the latent cache.

    ``absorb=False`` (paper-naive): up-project every cached latent each step.
    ``absorb=True`` (optimized): fold W_uk into the query and W_uv into the
    output projection so attention runs directly in the latent space —
    removes the (L, H, nope+v) materialization (see EXPERIMENTS.md §Perf).
    """
    B = x.shape[0]
    H, nope, rope, vdim = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    pos = jnp.asarray(pos, jnp.int32)
    vec = pos.ndim == 1           # per-row positions (serving; DESIGN.md §12)
    positions = pos[:, None] if vec else jnp.full((B, 1), pos, dtype=jnp.int32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(params, cfg, x, positions)
    if vec:
        c_cache = _store_rows(cache["c_kv"], c_kv_new, pos)
        r_cache = _store_rows(cache["k_rope"], k_rope_new, pos)
    else:
        c_cache = _dynamic_store(cache["c_kv"], c_kv_new, pos)
        r_cache = _dynamic_store(cache["k_rope"], k_rope_new, pos)
    L = c_cache.shape[1]
    if vec:
        valid = (jnp.arange(L)[None, :] <= pos[:, None])[:, None, None, :]
    else:
        valid = (jnp.arange(L) <= pos)[None, None, None, :]

    w_ukv = params["w_ukv"].reshape(cfg.kv_lora_rank, H, nope + vdim)
    w_uk, w_uv = w_ukv[..., :nope], w_ukv[..., nope:]

    if absorb:
        # q_lat: (B, 1, H, lora) = q_nope @ W_uk^T  (per head)
        q_lat = jnp.einsum("bthn,lhn->bthl", q_nope, w_uk)
        s = jnp.einsum("bthl,bLl->bhtL", q_lat, c_cache,
                       preferred_element_type=jnp.float32)
        s += jnp.einsum("bthr,bLkr->bhtL", q_rope, r_cache,
                        preferred_element_type=jnp.float32)
        s = s / np.sqrt(nope + rope)
        p = jax.nn.softmax(jnp.where(valid, s, NEG_INF), axis=-1)
        o_lat = jnp.einsum("bhtL,bLl->bthl", p.astype(c_cache.dtype), c_cache)
        out = jnp.einsum("bthl,lhv->bthv", o_lat, w_uv)
    else:
        k_nope, v = _mla_expand_kv(params, cfg, c_cache)   # (B, L, H, ·)
        s = jnp.einsum("bthn,bLhn->bhtL", q_nope, k_nope,
                       preferred_element_type=jnp.float32)
        s += jnp.einsum("bthr,bLkr->bhtL", q_rope, r_cache,
                        preferred_element_type=jnp.float32)
        s = s / np.sqrt(nope + rope)
        p = jax.nn.softmax(jnp.where(valid, s, NEG_INF), axis=-1)
        out = jnp.einsum("bhtL,bLhv->bthv", p.astype(v.dtype), v)
    out = out.reshape(B, 1, H * vdim) @ params["wo"]
    return out, {"c_kv": c_cache, "k_rope": r_cache}
