"""Mamba (selective SSM) block for the Jamba hybrid architecture.

Training/prefill runs the selective scan as a sequential ``lax.scan`` over
time (small HLO, exact).  Decode is the O(1) single-step state update.  The
recurrent state (B, d_inner, d_state) is the layer's "cache".

TPU note (DESIGN.md §5): the CUDA selective-scan kernel fuses the recurrence
into shared memory; on TPU the same insight maps to keeping the (d_inner,
d_state) state resident in VMEM across the time loop, which XLA does for a
``lax.scan`` carry.  A chunked associative-scan variant is the documented
perf alternative (trades memory for parallelism).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.scan_utils import chunked_scan
from repro.models.layers import ParamDesc
from repro.models.sharding_ctx import constrain, constrain_hard


def mamba_desc(cfg: ModelConfig) -> Dict[str, ParamDesc]:
    d, di, ds, dt = cfg.d_model, cfg.d_inner, cfg.ssm_d_state, cfg.dt_rank
    return {
        "in_proj": ParamDesc((d, 2 * di), ("embed", "inner")),
        "conv_w": ParamDesc((cfg.ssm_conv, di), (None, "inner"), "small"),
        "conv_b": ParamDesc((di,), ("inner",), "zeros"),
        "x_proj": ParamDesc((di, dt + 2 * ds), ("inner", None)),
        "dt_proj_w": ParamDesc((dt, di), (None, "inner"), "small"),
        "dt_proj_b": ParamDesc((di,), ("inner",), "ones"),
        "A_log": ParamDesc((di, ds), ("inner", "state"), "small"),
        "D": ParamDesc((di,), ("inner",), "ones"),
        "out_proj": ParamDesc((di, d), ("inner", "embed")),
    }


def _conv1d_causal(params, x):
    """Depthwise causal conv over time. x: (B, T, di)."""
    K = params["conv_w"].shape[0]
    pads = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pads[:, i:i + x.shape[1], :] * params["conv_w"][i] for i in range(K))
    return out + params["conv_b"]


def _sel_params(params, cfg, x):
    """x: (..., di) -> (dt (...,di), B (...,ds), C (...,ds))."""
    ds, dtr = cfg.ssm_d_state, cfg.dt_rank
    proj = x @ params["x_proj"]
    dt_in, Bc, Cc = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(dt_in @ params["dt_proj_w"] + params["dt_proj_b"])
    return dt, Bc, Cc


def mamba_forward(params, cfg: ModelConfig, x, return_state: bool = False):
    """x: (B, T, d) -> (B, T, d) [, final recurrent state]."""
    B, T, d = x.shape
    di, ds = cfg.d_inner, cfg.ssm_d_state
    xz = x @ params["in_proj"]
    xin_raw, z = jnp.split(xz, 2, axis=-1)
    xin = jax.nn.silu(_conv1d_causal(params, xin_raw))
    dt, Bc, Cc = _sel_params(params, cfg, xin)          # (B,T,di),(B,T,ds),(B,T,ds)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))   # (di, ds)

    out_dtype = x.dtype

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp                        # (B,di),(B,di),(B,ds),(B,ds)
        x_t, dt_t, B_t, C_t = (t.astype(jnp.float32) for t in (x_t, dt_t, B_t, C_t))
        dA = jnp.exp(dt_t[..., None] * A)                # (B,di,ds)
        dBx = dt_t[..., None] * B_t[:, None, :] * x_t[..., None]
        h = h * dA + dBx
        y = jnp.einsum("bds,bs->bd", h, C_t)
        return h, y.astype(out_dtype)                    # keep the stacked ys small

    h0 = constrain_hard(jnp.zeros((B, di, ds), jnp.float32), ("b", "m", None))
    c3 = lambda a: constrain(a, (None, "b", "m"))
    # stacks stay in compute dtype (bf16) in HBM; the step upcasts.
    xs = (c3(xin.transpose(1, 0, 2)),
          c3(dt.transpose(1, 0, 2)),
          constrain(Bc.transpose(1, 0, 2), (None, "b", None)),
          constrain(Cc.transpose(1, 0, 2), (None, "b", None)))
    h_final, ys = chunked_scan(step, h0, xs, chunk=128)
    y = constrain(ys, (None, "b", "m")).transpose(1, 0, 2).astype(x.dtype)
    y = y + xin * params["D"]
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    if return_state:
        K = cfg.ssm_conv
        tail = jnp.pad(xin_raw, ((0, 0), (max(0, K - 1 - T), 0), (0, 0)))[:, -(K - 1):, :]
        return out, {"h": h_final, "conv": tail}
    return out


def init_mamba_state(cfg: ModelConfig, batch: int, dtype):
    di, ds, K = cfg.d_inner, cfg.ssm_d_state, cfg.ssm_conv
    return {"h": jax.ShapeDtypeStruct((batch, di, ds), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, K - 1, di), dtype)}


def mamba_decode(params, cfg: ModelConfig, x, state) -> Tuple[jnp.ndarray, Dict]:
    """One-token step. x: (B, 1, d); state: {'h','conv'}."""
    B = x.shape[0]
    xz = x[:, 0] @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([state["conv"], xin[:, None, :]], axis=1)  # (B,K,di)
    conv = jnp.einsum("bkd,kd->bd", window, params["conv_w"]) + params["conv_b"]
    xin_c = jax.nn.silu(conv)
    dt, Bc, Cc = _sel_params(params, cfg, xin_c)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A)
    dBx = (dt[..., None] * Bc[:, None, :] * xin_c[..., None]).astype(jnp.float32)
    h = state["h"] * dA + dBx
    y = jnp.einsum("bds,bs->bd", h, Cc.astype(jnp.float32)).astype(x.dtype)
    y = y + xin_c * params["D"]
    y = y * jax.nn.silu(z)
    out = (y @ params["out_proj"])[:, None, :]
    return out, {"h": h, "conv": window[:, 1:, :]}
