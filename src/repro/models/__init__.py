from repro.models.model import Model, count_params  # noqa: F401
