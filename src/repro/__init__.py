"""Survey reproduction package.  Importing any ``repro.*`` module installs
the JAX version-compat shims first (see ``repro.compat``)."""
from repro import compat  # noqa: F401
