from repro.checkpoint.checkpoint import (  # noqa: F401
    latest_step, load_arrays, restore, save, verify)
