"""Checkpointing: save/restore arbitrary pytrees (params, optimizer state,
comm-optimizer state, data-pipeline step) as a flat .npz plus a JSON
manifest of the tree structure.

Sharded-aware: arrays are gathered to host before writing and re-placed with
``jax.device_put(..., sharding)`` on restore, so the same checkpoint moves
between mesh layouts (the usual resharding-restore pattern).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save(path: str, tree, step: Optional[int] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(path + ".npz", **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    with open(path + ".json", "w") as f:
        json.dump({"treedef": str(treedef), "step": step,
                   "keys": sorted(arrays)}, f)


def restore(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedSharding to place shards directly."""
    data = np.load(path + ".npz")
    flat_like = _flatten_with_paths(like)
    flat_shard = _flatten_with_paths(shardings) if shardings is not None else None
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out = []
    for path_key, leaf in flat_like.items():
        arr = data[path_key]
        if flat_shard is not None:
            arr = jax.device_put(arr, flat_shard[path_key])
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(path: str) -> Optional[int]:
    try:
        with open(path + ".json") as f:
            return json.load(f).get("step")
    except FileNotFoundError:
        return None
