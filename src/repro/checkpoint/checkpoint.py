"""Checkpointing: save/restore arbitrary pytrees (params, optimizer state,
comm-optimizer state, data-pipeline step) as a flat .npz plus a JSON
manifest of the tree structure.

Sharded-aware: arrays are gathered to host before writing and re-placed with
``jax.device_put(..., sharding)`` on restore, so the same checkpoint moves
between mesh layouts (the usual resharding-restore pattern).

Crash-safe (DESIGN.md §15): both files are written to temporary siblings
and ``os.replace``-d into place, so a kill mid-write leaves either the
previous checkpoint or none — never a truncated file that loads as
garbage.  The manifest additionally records a sha256 of the array payload,
verified BEFORE any array is deserialized: a torn write that lands between
the two renames (or bit rot on disk) raises a loud :class:`ValueError`
instead of restoring silently corrupt state.  Manifests written before the
checksum existed (no ``"sha256"`` key) still load — verification is
skipped for them, keeping old checkpoints readable.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def _sha256_file(path: str, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk)
            if not b:
                break
            h.update(b)
    return h.hexdigest()


def _atomic_bytes(path: str, write_fn) -> str:
    """Write via a temp sibling + ``os.replace`` (atomic on POSIX within a
    filesystem); returns the sha256 of the written bytes."""
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
        digest = _sha256_file(tmp)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise
    return digest


def save(path: str, tree, step: Optional[int] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    # np.savez appends ".npz" to bare paths but honors open file handles —
    # the handle form is what lets the payload go through the atomic tmp
    digest = _atomic_bytes(path + ".npz", lambda f: np.savez(f, **arrays))
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {"treedef": str(treedef), "step": step,
                "keys": sorted(arrays), "sha256": digest}
    _atomic_bytes(path + ".json",
                  lambda f: f.write(json.dumps(manifest).encode()))


def verify(path: str) -> Dict[str, Any]:
    """Check the ``.npz`` payload against the manifest's sha256; returns
    the manifest.  Raises :class:`ValueError` on mismatch (truncated or
    corrupt checkpoint) BEFORE anything is deserialized.  Pre-checksum
    manifests (no ``"sha256"`` key) pass unverified."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    want = manifest.get("sha256")
    if want is not None:
        got = _sha256_file(path + ".npz")
        if got != want:
            raise ValueError(
                f"checkpoint {path!r} is truncated or corrupt: payload "
                f"sha256 {got[:16]}… does not match the manifest's "
                f"{want[:16]}… — restore refused (a kill mid-write, torn "
                f"rename, or on-disk corruption)")
    return manifest


def load_arrays(path: str) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Checksum-verified raw load: ``({path_key: array}, manifest)``.
    The structure-agnostic entry point ``TrainSession.load_checkpoint``
    restores through (leaf-shaped payloads are mode-portable)."""
    manifest = verify(path)
    with np.load(path + ".npz") as data:
        arrays = {k: data[k] for k in data.files}
    return arrays, manifest


def restore(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedSharding to place shards directly.  The payload checksum is
    verified first (:func:`verify`)."""
    verify(path)
    data = np.load(path + ".npz")
    flat_like = _flatten_with_paths(like)
    flat_shard = _flatten_with_paths(shardings) if shardings is not None else None
    leaves, treedef = jax.tree_util.tree_flatten(like)
    out = []
    for path_key, leaf in flat_like.items():
        arr = data[path_key]
        if flat_shard is not None:
            arr = jax.device_put(arr, flat_shard[path_key])
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def latest_step(path: str) -> Optional[int]:
    try:
        with open(path + ".json") as f:
            return json.load(f).get("step")
    except FileNotFoundError:
        return None
