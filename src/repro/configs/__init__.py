"""Architecture registry: ``get_config(name)``, ``reduced(cfg)``, shape table."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    LayerSpec, ModelConfig, Segment, ShapeConfig, SHAPES,
    TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K, reduced,
)

_ARCH_MODULES = {
    "deepseek-67b": "deepseek_67b",
    "gemma2-9b": "gemma2_9b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "gemma-2b": "gemma_2b",
    "gemma3-4b": "gemma3_4b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "chameleon-34b": "chameleon_34b",
    "xlstm-125m": "xlstm_125m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "jamba-v0.1-52b": "jamba_v01_52b",
}

ALL_ARCHS = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    return mod.CONFIG


def applicable_shapes(cfg: ModelConfig) -> tuple[str, ...]:
    """Which of the four assigned input shapes run for this arch.

    ``long_500k`` requires sub-quadratic attention (SSM / hybrid / sliding
    window); pure full-attention archs skip it (see DESIGN.md §4).
    """
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        shapes.append("long_500k")
    return tuple(shapes)
