"""Gemma-3 4B — 5:1 local:global attention, 128k context [hf:google/gemma-3-1b-pt family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    activation="geglu",
    qk_norm=True,
    attn_pattern=("local", "local", "local", "local", "local", "global"),
    window_size=1024,
    embed_scale=True,
    norm_offset=True,
    rope_theta=1000000.0,
    subquadratic=True,  # only 1/6 layers carry a full-length KV cache
)
