"""Chameleon-34B — early-fusion VLM; VQ image tokens share the 65536 vocab,
so the backbone consumes token ids directly (the VQ tokenizer is the allowed
modality-frontend stub). QK-norm per the paper. [arXiv:2405.09818]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    activation="swiglu",
    qk_norm=True,
)
