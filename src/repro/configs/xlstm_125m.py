"""xLSTM-125M — sLSTM + mLSTM blocks (3:1), attention-free [arXiv:2405.04517]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,                 # xLSTM blocks carry their own up/down projections
    vocab_size=50304,
    slstm_every=4,          # layers 3, 7, 11 are sLSTM; others mLSTM
    mlstm_chunk=64,         # bounds per-chunk carry memory of the (dh, dh) matrix state
    subquadratic=True,      # O(1) recurrent state
)
