"""SeamlessM4T-large v2 — encoder-decoder, multimodal (speech) [arXiv:2308.11596].

The mel-spectrogram + conformer feature extractor is the allowed modality
frontend STUB: ``input_specs()`` supplies precomputed frame embeddings of
shape (batch, frames, d_model) to the 24-layer text/decoder transformer.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,            # decoder layers
    num_encoder_layers=24,
    is_encoder_decoder=True,
    embedding_inputs=True,    # encoder consumes precomputed frame embeddings
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,        # padded to 256256 internally for TP divisibility
    activation="geglu",
)
