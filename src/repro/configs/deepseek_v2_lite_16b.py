"""DeepSeek-V2-Lite 16B — MLA (kv_lora=512), 2 shared + 64 routed top-6 [arXiv:2405.04434]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    activation="swiglu",
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=64,
    top_k=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    moe_every=1,
    first_dense=1,
)
