"""Config system: architecture configs, input shapes, layer stacking plans.

Every assigned architecture is a ``ModelConfig`` in ``repro.configs.<id>``;
``get_config(name)`` resolves them, ``reduced(cfg)`` builds the CPU-smoke
variant (2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer specs & stacking plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerSpec:
    """One layer of the stack: a (token-)mixer plus an optional FFN."""
    mixer: str = "attn"           # attn | mla | mamba | slstm | mlstm
    window: Optional[int] = None  # sliding-window size; None = global attention
    ffn: str = "dense"            # dense | moe | none


@dataclass(frozen=True)
class Segment:
    """``repeats`` copies of a (possibly heterogeneous) ``period`` of layers.

    Lowered as one ``lax.scan`` over ``repeats`` with the period unrolled in
    the body, so HLO size is O(len(period)) rather than O(num_layers).
    """
    period: Tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.period) * self.repeats


def plan_from_pattern(pattern: Sequence[LayerSpec], num_layers: int) -> Tuple[Segment, ...]:
    """Tile ``pattern`` to ``num_layers``, emitting a scanned segment for the
    divisible part plus an unrolled remainder segment."""
    p = len(pattern)
    reps, rem = divmod(num_layers, p)
    segs = []
    if reps:
        segs.append(Segment(tuple(pattern), reps))
    if rem:
        segs.append(Segment(tuple(pattern[:rem]), 1))
    return tuple(segs)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default: d_model // num_heads
    activation: str = "swiglu"        # swiglu | geglu
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    qk_norm: bool = False             # chameleon / gemma3
    attn_logit_softcap: Optional[float] = None   # gemma2: 50.0
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0
    embed_scale: bool = False         # gemma family: x *= sqrt(d_model)
    norm_offset: bool = False         # gemma RMSNorm (1 + w)

    # attention pattern: e.g. ("local","global") alternating; "local" uses window
    attn_pattern: Tuple[str, ...] = ("global",)
    window_size: int = 4096

    # MoE
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                 # per-expert hidden; 0 -> use d_ff
    moe_every: int = 1                # MoE FFN every k-th layer (jamba: 2)
    moe_offset: int = 0               # phase of the MoE layers within the period
    first_dense: int = 0              # first N layers use dense FFN (deepseek-v2)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # MLA (deepseek-v2)
    use_mla: bool = False
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # SSM / hybrid
    ssm_d_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0              # 0 -> ceil(d_model/16)
    attn_every: int = 0               # jamba: attention layer every k-th (else mamba)
    attn_offset: int = 0              # index within period that is attention

    # xLSTM
    slstm_every: int = 0              # sLSTM every k-th layer (else mLSTM)
    mlstm_chunk: int = 256            # chunk length (both recurrence forms)
    mlstm_parallel: bool = False      # chunkwise-PARALLEL mLSTM (MXU matmuls)

    # encoder-decoder (audio)
    num_encoder_layers: int = 0
    is_encoder_decoder: bool = False
    # modality frontend stub: inputs are precomputed embeddings (B, S, d_model)
    embedding_inputs: bool = False

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return int(math.ceil(self.vocab_size / 256) * 256)

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or int(math.ceil(self.d_model / 16))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_spec(self, i: int) -> LayerSpec:
        """Spec of layer ``i`` (decoder stack)."""
        if self.family == "ssm":  # xLSTM
            mixer = "slstm" if (self.slstm_every and i % self.slstm_every == self.slstm_every - 1) else "mlstm"
            return LayerSpec(mixer=mixer, ffn="none")
        if self.attn_every:  # hybrid (jamba)
            mixer = "attn" if i % self.attn_every == self.attn_offset else "mamba"
        elif self.use_mla:
            mixer = "mla"
        else:
            mixer = "attn"
        window = None
        if mixer == "attn" and self.attn_pattern:
            kind = self.attn_pattern[i % len(self.attn_pattern)]
            window = self.window_size if kind == "local" else None
        if (self.num_experts and i >= self.first_dense
                and i % self.moe_every == self.moe_offset % self.moe_every):
            ffn = "moe"
        else:
            ffn = "dense"
        return LayerSpec(mixer=mixer, window=window, ffn=ffn)

    def stack_plan(self) -> Tuple[Segment, ...]:
        """Group the per-layer specs into scannable segments."""
        specs = [self.layer_spec(i) for i in range(self.num_layers)]
        # find the shortest period that tiles the prefix-free part
        period = self._period_len()
        segs = []
        i = 0
        # leading irregular layers (e.g. deepseek-v2 first dense layer)
        while i < self.num_layers and i < self.first_dense:
            segs.append(Segment((specs[i],), 1))
            i += 1
        rest = specs[i:]
        if rest:
            p = period
            reps, rem = divmod(len(rest), p)
            if reps:
                segs.append(Segment(tuple(rest[:p]), reps))
            if rem:
                segs.append(Segment(tuple(rest[reps * p:]), 1))
        return tuple(segs)

    def _period_len(self) -> int:
        cands = [1]
        if len(self.attn_pattern) > 1:
            cands.append(len(self.attn_pattern))
        if self.attn_every:
            cands.append(self.attn_every)
        if self.num_experts and self.moe_every > 1:
            cands.append(self.moe_every)
        if self.slstm_every:
            cands.append(self.slstm_every)
        l = 1
        for c in cands:
            l = l * c // math.gcd(l, c)
        return l

    def num_params(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        from repro.models.model import count_params  # lazy import
        return count_params(self)


def reduced(cfg: ModelConfig, seq_cap: int = 128) -> ModelConfig:
    """CPU-smoke variant of the same family: 2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4)
    kv = max(1, min(cfg.num_kv_heads, heads))
    upd = dict(
        name=cfg.name + "-reduced",
        num_layers=2 if not cfg.attn_every else min(cfg.num_layers, cfg.attn_every),
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=64 if cfg.head_dim else None,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else cfg.d_ff,
        vocab_size=min(cfg.vocab_size, 1024),
        window_size=min(cfg.window_size, 32),
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.num_experts:
        upd.update(num_experts=4, top_k=min(cfg.top_k, 2),
                   moe_d_ff=min(cfg.moe_d_ff or cfg.d_ff, 128),
                   num_shared_experts=min(cfg.num_shared_experts, 1))
    if cfg.use_mla:
        upd.update(kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32)
    if cfg.attn_every:
        upd.update(num_layers=cfg.attn_every)  # one full hybrid period
    if cfg.is_encoder_decoder:
        upd.update(num_encoder_layers=2)
    if cfg.family == "ssm":
        upd.update(num_layers=max(2, cfg.slstm_every or 2), mlstm_chunk=16)
    return dataclasses.replace(cfg, **upd)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    phase: str                # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
