"""Jamba v0.1 52B — Mamba:attention 7:1 interleave, MoE 16e top-2 every other
layer [arXiv:2403.19887]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    activation="swiglu",
    attn_every=8,            # one attention layer per 8-layer Jamba block
    attn_offset=3,
    num_experts=16,
    top_k=2,
    moe_d_ff=14336,
    moe_every=2,             # MoE replaces the MLP on every other layer
    moe_offset=1,
    ssm_d_state=16,
    ssm_conv=4,
    ssm_expand=2,
    subquadratic=True,       # only 4/32 layers carry KV caches
)
