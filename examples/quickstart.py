"""Quickstart: build a reduced architecture, train a few steps with a
compressed + ring-allreduce gradient sync, then decode from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core import GradientSynchronizer, SyncConfig
from repro.data import DataConfig, SyntheticPipeline
from repro.launch.serve import generate
from repro.models import Model
from repro.optim import apply_updates, make_optimizer


def main():
    cfg = reduced(get_config("gemma-2b"))
    model = Model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)

    opt = make_optimizer("adam", lr=3e-3)
    opt_state = opt.init(params)

    # the paper's technique: compress gradients (top-1% + error feedback)
    # before the ring allreduce.  On 1 device the collective degenerates but
    # the compression path is identical.
    sync = GradientSynchronizer(
        SyncConfig(compressor="topk", compressor_args=(("ratio", 0.05),),
                   algo="ring"), axes=())
    sync_state = sync.init_state(params)

    data = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=64, global_batch=8))

    @jax.jit
    def step(params, opt_state, sync_state, batch, i, rng):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, sync_state = sync(grads, sync_state, rng)
        updates, opt_state = opt.update(grads, opt_state, params, i)
        return apply_updates(params, updates), opt_state, sync_state, loss

    print(f"model: {cfg.name}, params: "
          f"{sum(x.size for x in jax.tree.leaves(params)):,}")
    print(f"wire bits/step: {sync.payload_bits(params):,} "
          f"(dense: {sum(x.size for x in jax.tree.leaves(params)) * 32:,})")
    for i in range(30):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        params, opt_state, sync_state, loss = step(
            params, opt_state, sync_state, batch, jnp.asarray(i),
            jax.random.fold_in(rng, i))
        if i % 10 == 0:
            print(f"step {i:3d}  loss {float(loss):.4f}")

    toks = generate(model, params,
                    jnp.asarray(data.batch(99)["tokens"][:2, :16]), gen=8,
                    max_len=32, rng=rng)
    print("decoded:", toks[0].tolist())
    assert jnp.all(jnp.isfinite(jnp.asarray(toks)))
    print("quickstart OK")


if __name__ == "__main__":
    main()
