"""Batched serving example (deliverable b): drive the continuous-batching
engine directly — paged KV cache, staggered arrivals, mid-stream
admission — across three architecture families (dense GQA, MLA+MoE, SSM),
then a 2-replica routed run.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serve import Engine, MultiReplicaServer, Request, ServeConfig
from repro.serve.engine import latency_summary, poisson_trace


def trace(vocab, n=6, prompt_len=16):
    return poisson_trace(n, mean_interarrival_s=0.05, prompt_len=prompt_len,
                         max_new_choices=[4, 8], vocab=vocab, seed=0)


def main():
    for arch in ("gemma-2b", "deepseek-v2-lite-16b", "xlstm-125m"):
        cfg = reduced(get_config(arch))
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params,
                     ServeConfig(max_batch=3, max_len=24, page_size=8))
        comps = eng.run(trace(cfg.vocab_size))
        s = latency_summary(comps)
        print(f"{cfg.name}: {len(comps)} requests, {s['tokens']} tokens, "
              f"prefills={eng.prefills} decode_ticks={eng.decode_ticks}, "
              f"compiles={eng.compile_counts()}")
        assert all(np.isfinite(c.tokens).all() for c in comps)

    # 2-replica routed serving on the dense config
    cfg = reduced(get_config("gemma-2b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = MultiReplicaServer(
        [Engine(model, params, ServeConfig(max_batch=2, max_len=24,
                                           page_size=8)) for _ in range(2)])
    comps = srv.run(trace(cfg.vocab_size))
    print(f"2 replicas: routes={srv.routes}, "
          f"{latency_summary(comps)['tokens']} tokens")
    print("serve_batched OK")


if __name__ == "__main__":
    main()
