"""Batched serving example (deliverable b): prefill a batch of prompts and
decode continuations with KV caches / recurrent state, across three
architecture families (dense GQA, MLA+MoE, SSM).

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax

from repro.launch import serve as serve_mod


def main():
    for arch in ("gemma-2b", "deepseek-v2-lite-16b", "xlstm-125m"):
        serve_mod.main(["--arch", arch, "--batch", "4",
                        "--prompt-len", "24", "--gen", "12"])
    print("serve_batched OK")


if __name__ == "__main__":
    main()
