"""Compression ablation (survey §3.2 in miniature): train the same reduced
model with each gradient compressor and report final losses + wire bytes —
the accuracy/compression trade-off the survey's Fig. 7 discusses.

    PYTHONPATH=src python examples/compression_ablation.py [--steps 80]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.core import GradientSynchronizer, SyncConfig
from repro.data import DataConfig, SyntheticPipeline
from repro.models import Model
from repro.optim import apply_updates, make_optimizer

CASES = [
    ("none", ()),
    ("sign", ()),
    ("int8", ()),
    ("qsgd", (("levels", 15),)),
    ("topk", (("ratio", 0.05),)),
    ("powersgd", (("rank", 4),)),
]


def train_once(compressor, cargs, steps, seed=0):
    cfg = reduced(get_config("xlstm-125m"))
    model = Model(cfg)
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    opt = make_optimizer("adam", lr=3e-3)
    opt_state = opt.init(params)
    sync = GradientSynchronizer(
        SyncConfig(compressor=compressor, compressor_args=cargs, algo="ring"),
        axes=())
    sync_state = sync.init_state(params)
    data = SyntheticPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                        seq_len=64, global_batch=8))

    @jax.jit
    def step(params, opt_state, sync_state, batch, i, rng):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, sync_state = sync(grads, sync_state, rng)
        updates, opt_state = opt.update(grads, opt_state, params, i)
        return apply_updates(params, updates), opt_state, sync_state, loss

    loss = None
    for i in range(steps):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        params, opt_state, sync_state, loss = step(
            params, opt_state, sync_state, batch, jnp.asarray(i),
            jax.random.fold_in(rng, i))
    bits = sync.payload_bits(params)
    return float(loss), bits


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()
    print(f"{'compressor':<10} {'final_loss':>10} {'wire_bits':>12} {'ratio':>7}")
    dense_bits = None
    for name, cargs in CASES:
        loss, bits = train_once(name, cargs, args.steps)
        dense_bits = dense_bits or bits
        print(f"{name:<10} {loss:>10.4f} {bits:>12,} "
              f"{dense_bits / bits:>6.1f}x")
    print("ablation OK")


if __name__ == "__main__":
    main()
