"""End-to-end training driver (deliverable b): train a ~100M-parameter
xLSTM for a few hundred steps through the full launcher stack (config ->
data pipeline -> comm-optimized step -> checkpoint).

Full run (~100M params, a few hundred steps — takes a while on CPU):
    PYTHONPATH=src python examples/train_e2e.py --full

CI-sized run (reduced model, 60 steps, asserts the loss dropped):
    PYTHONPATH=src python examples/train_e2e.py
"""
import argparse
import sys

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="train the real 125M xlstm config (slow on CPU)")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    if args.full:
        argv = ["--arch", "xlstm-125m", "--steps", str(args.steps or 300),
                "--batch", "8", "--seq", "256", "--lr", "1e-3",
                "--sync", "comm", "--compressor", "int8", "--algo", "ring",
                "--checkpoint", "/tmp/repro_e2e_ckpt"]
    else:
        argv = ["--arch", "xlstm-125m", "--reduced", "--steps",
                str(args.steps or 60), "--batch", "8", "--seq", "64",
                "--lr", "3e-3", "--sync", "comm", "--compressor", "int8",
                "--algo", "ring", "--checkpoint", "/tmp/repro_e2e_ckpt"]
    losses = train_mod.main(argv)
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])
    print(f"e2e OK: loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
