"""CI benchmark-regression harness (ISSUE 4 satellite).

Runs the MODELED planner benches — planner / sharded / pipeline — fully
deterministically (abstract params + the α-β cost model; no wall-clock
timing, so the numbers are bit-stable across machines), writes one
``BENCH_<suite>.json`` per suite, and fails CI when any tracked number
regresses more than ``--tolerance`` (default 10%) against the committed
baselines in ``benchmarks/baselines/``.

    PYTHONPATH=src python scripts/bench_ci.py                 # gate
    PYTHONPATH=src python scripts/bench_ci.py --write-baselines
    PYTHONPATH=src python scripts/bench_ci.py --perturb 0.2   # negative test

The ``--perturb`` flag multiplies every computed number by (1 + p) before
the comparison — the injected-regression negative test the CI workflow
runs to prove the gate actually trips.

Record schema (per suite file)::

    {"<arch>/<link>/<point>": {"modeled_step_ms": 12.345, "arm": "..."},
     ...}

A record may name a different gated quantity via ``"metric": "<key>"``
(default ``modeled_step_ms``); extra keys are informational.

Tracked points are the acceptance quantities of each execution mode: the
auto plan and the fixed baselines it must beat (planner), the
replicated/sharded fixed modes and the budget flip (sharded), the fixed DP
arms vs the best pipeline arm and the budget pick (pipeline), the
per-family budget-eligible bests of the TP×PP×DP×EP placement search on
the acceptance points (parallelism, ISSUE 9), on the
tiered networks (ISSUE 5) the flat-ring bound vs the hierarchical fixed
plan vs the tier-aware auto pick per topology (topology) — and the fused
Pallas wires (DESIGN.md §11, the ``kernels`` suite): the only MEASURED
suite, gating the fused/unfused wall-clock RATIO per (wire × bucket size ×
stage), which is machine-portable where absolute microseconds are not
(those are recorded informationally).  A ratio drifting >10% above its
committed value means the fused path lost its advantage — the
perf-regression signal this PR's acceptance pins.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)  # benchmarks.* (shared point definitions)

ARCHS = ("xlstm-125m", "gemma-2b", "chameleon-34b")
REGIMES = ("fast_ici", "commodity")
# tiered networks tracked by the topology suite (TOPOLOGY_PRESETS names)
TOPOLOGIES = ("two_tier_pod", "commodity_cluster")
PEAK_FLOPS = 197e12
TOKENS = 4096
WORLD = 256
OPT = "adam"


# kernels suite: gated bucket sizes (f32 elements).  32 MiB is the
# repo's DEFAULT bucket size; the gated points sit at and above the
# last-level cache, where the one-pass fused kernel's
# fewer-HBM-passes advantage is load-bearing on every backend.  Below
# the LLC the decomposed chain is cache-resident and XLA-CPU can favor
# it — the off-TPU gap DESIGN.md §11 documents; the small-bucket
# crossover is reported (not gated) by benchmarks/bench_collectives.
KERNEL_SIZES = ((1 << 23, "32MiB"), (1 << 24, "64MiB"))
KERNEL_WORLD = 8


def _ratio_us(f_fused, f_unfused, args_f, args_u, repeats: int = 5,
              rounds: int = 3):
    """(fused_us, unfused_us, ratio): the MEDIAN over ``rounds``
    independent estimates, each an interleaved min-of-N of both arms
    (fused, unfused, fused, ... so a load shift hits both minima alike).
    The median-of-rounds is what makes the gated ratio repeatable on a
    shared machine — single min-of-N estimates spread ~±8% run to run."""
    import time as _time

    import jax
    jax.block_until_ready(f_fused(*args_f))      # compile / warm
    jax.block_until_ready(f_unfused(*args_u))
    est = []
    for _ in range(rounds):
        bf = bu = float("inf")
        for _ in range(repeats):
            t0 = _time.perf_counter()
            jax.block_until_ready(f_fused(*args_f))
            bf = min(bf, _time.perf_counter() - t0)
            t0 = _time.perf_counter()
            jax.block_until_ready(f_unfused(*args_u))
            bu = min(bu, _time.perf_counter() - t0)
        est.append((bf / bu, bf * 1e6, bu * 1e6))
    est.sort()
    return est[len(est) // 2]


def collect_kernels() -> dict:
    """The measured fused-wire records: wall time of the fused one-pass
    kernel vs the decomposed chain (one jitted op per stage, every
    intermediate materialized — the multi-pass HBM traffic fusion
    removes), per wire × bucket size × stage.  The gated metric is
    ``fused_over_unfused`` — fused must stay at or below the committed
    fraction of the decomposed time; absolute microseconds are recorded
    informationally (they are not machine-portable)."""
    import jax
    import jax.numpy as jnp

    from repro.core.compression import get_compressor
    from repro.kernels import ops
    from repro.kernels import ref as kref

    tile = ops.TILE
    add = jax.jit(jnp.add)
    sub = jax.jit(jnp.subtract)
    quant = jax.jit(lambda c: kref.quantize_tiles_ref(c, tile=tile))
    deq = jax.jit(lambda q, s: kref.dequantize_ref(q, s, tile=tile))
    mask = jax.jit(lambda c: kref.topk_mask_bisect_ref(c, ratio=0.01,
                                                       tile=tile, iters=16))
    i8 = get_compressor("int8_fused")
    tk = get_compressor("topk_fused")
    f_enc_i8 = jax.jit(lambda g, e: i8.fused_ef_compress(g, e, 1.0))
    f_enc_tk = jax.jit(lambda g, e: tk.fused_ef_compress(g, e, 1.0))

    def record(est) -> dict:
        ratio, fused_us, unfused_us = est
        return {"metric": "fused_over_unfused",
                "fused_over_unfused": round(ratio, 4),
                "fused_us": round(fused_us, 1),
                "unfused_us": round(unfused_us, 1)}

    def unfused_enc_i8(g, e):
        c = add(g, e)
        q, s = quant(c)
        return q, s, sub(c, deq(q, s))

    def unfused_enc_tk(g, e):
        c = add(g, e)
        y = mask(c)
        return y, sub(c, y)

    kernels: dict = {}
    for n, tag in KERNEL_SIZES:
        g = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
        e = jnp.zeros_like(g)
        kernels[f"int8_fused/{tag}/encode"] = record(
            _ratio_us(f_enc_i8, unfused_enc_i8, (g, e), (g, e)))

    # the heavier stages are tracked at the default bucket size only,
    # bounding the suite's wall time
    n, tag = KERNEL_SIZES[0]
    g = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
    e = jnp.zeros_like(g)
    kernels[f"topk_fused/{tag}/encode"] = record(
        _ratio_us(f_enc_tk, unfused_enc_tk, (g, e), (g, e)))

    (q1, s1), meta, _ = i8.fused_ef_compress(g, e, 1.0)
    qg = jnp.stack([q1] * KERNEL_WORLD)
    sg = jnp.stack([s1] * KERNEL_WORLD)
    f_dec = jax.jit(lambda q, s: i8.fused_decode_sum((q, s), meta))

    def unfused_dec(q, s):
        acc = jnp.zeros((n,), jnp.float32)
        for w in range(KERNEL_WORLD):
            acc = add(acc, deq(q[w], s[w]))
        return acc

    kernels[f"int8_fused/{tag}/decode"] = record(
        _ratio_us(f_dec, unfused_dec, (qg, sg), (qg, sg)))
    return kernels


def _profiles():
    import jax
    import numpy as np

    from repro.core.schedule import profiles_from_grads
    from repro.configs import get_config
    from repro.models import Model
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch)
        params = Model(cfg).abstract_params()
        n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        t_backward = 4.0 * n * TOKENS / PEAK_FLOPS
        out[arch] = (cfg, profiles_from_grads(params, t_backward))
    return out


def collect_serving() -> dict:
    """Serving suite (DESIGN.md §12): fully deterministic — the engine's
    admission/retirement state machine runs on a :class:`SimClock` with
    modeled per-step costs (no wall-clock timing, no device work), and
    the placement rows come from the α-β decode cost model.  Gated
    numbers: simulated trace makespans for continuous and static
    batching, their ratio (continuous/static — rising means the
    continuous engine lost scheduling efficiency), and the planner's
    per-arm decode step times for gemma-2b on two_tier_pod."""
    from repro.configs import get_config, reduced
    from repro.core.schedule import (TOPOLOGY_PRESETS, Topology,
                                     plan_serving)
    from repro.models import Model
    from repro.models.model import count_params
    from repro.serve import (Engine, Request, ServeConfig, SimCosts,
                             run_static)
    from repro.serve.engine import latency_summary

    import numpy as np

    serving: dict = {}
    cfg = reduced(get_config("gemma-2b"))
    model = Model(cfg)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=(8,)).astype(np.int32),
                    max_new=24 if i % 4 == 0 else 4,
                    arrival_s=0.0) for i in range(12)]
    sim = SimCosts(prefill_s_per_token=2e-4, decode_step_s=2e-3)
    eng = Engine(model, None, ServeConfig(max_batch=4, max_len=32,
                                          page_size=8), sim=sim)
    cont = latency_summary(eng.run(reqs))
    stat = latency_summary(run_static(model, None, reqs, 4, 32, sim=sim))
    serving["gemma-2b/sim/continuous"] = {
        "metric": "sim_makespan_ms", "sim_makespan_ms":
        cont["makespan_s"] * 1e3, "arm": "continuous"}
    serving["gemma-2b/sim/static"] = {
        "metric": "sim_makespan_ms", "sim_makespan_ms":
        stat["makespan_s"] * 1e3, "arm": "static"}
    serving["gemma-2b/sim/speedup"] = {
        "metric": "continuous_over_static_makespan",
        "continuous_over_static_makespan":
        cont["makespan_s"] / stat["makespan_s"],
        "arm": f"cont {cont['tokens_per_s']:.0f} tok/s vs "
               f"stat {stat['tokens_per_s']:.0f}"}

    full = get_config("gemma-2b")
    pb = count_params(full) * 2.0
    net = Topology.from_spec(TOPOLOGY_PRESETS["two_tier_pod"])
    best, arms = plan_serving(net, net.world, pb, full.num_layers,
                              full.d_model, batch=8)
    for a in arms:
        serving[f"gemma-2b/two_tier_pod/{a.key()}"] = {
            "metric": "step_ms", "step_ms": a.step_s * 1e3,
            "arm": "best" if a.key() == best.key() else ""}
    return serving


def collect_calibration() -> dict:
    """Calibration suite (DESIGN.md §13): fully deterministic — the
    per-tier α/β link fit replayed over RECORDED collective timings
    (``benchmarks/fixtures/calibration_timings.json``, a synthetic
    two-tier fabric with known ground truth plus fixed additive noise),
    never live timings.  Gated numbers per tier: fitted α (µs), fitted β
    (ps/byte) and the fit residual (µs) — a drift in any of them means
    the fit pipeline changed what it extracts from identical data.  Plus
    the canned drift-report math (drift % and the modeled wall step),
    which must stay exact."""
    from repro.core.schedule import (Topology, calibrate_topology,
                                     drift_fraction, modeled_wall_step_s)

    with open(os.path.join(REPO, "benchmarks", "fixtures",
                           "calibration_timings.json")) as f:
        fx = json.load(f)
    lookup = {(s["tier"], s["algo"], s["p"], s["n_bytes"]): s["seconds"]
              for s in fx["samples"]}

    def timer(algo, tier, p, n_bytes):
        return lookup[(tier, algo, int(p), float(n_bytes))]

    cal = calibrate_topology(Topology.from_spec(fx["spec"]), timer=timer,
                             sizes=fx["sizes"], algos=fx["algos"])
    out: dict = {}
    for name, fit in cal.fits:
        out[f"{fx['spec']}/{name}/alpha"] = {
            "metric": "alpha_us", "alpha_us": fit.alpha_s * 1e6,
            "arm": f"R2={fit.r2:.4f}"}
        out[f"{fx['spec']}/{name}/beta"] = {
            "metric": "beta_ps_per_byte",
            "beta_ps_per_byte": fit.beta_s_per_byte * 1e12,
            "arm": f"{1.0 / fit.beta_s_per_byte / 1e9:.2f} GB/s"}
        out[f"{fx['spec']}/{name}/rms"] = {
            "metric": "fit_rms_us", "fit_rms_us": fit.rms_s * 1e6,
            "arm": f"n={fit.n_samples}"}
    # canned drift math: exact by construction, gated at exact values
    out["drift/canned_20pct"] = {
        "metric": "drift_pct",
        "drift_pct": drift_fraction(10e-3, 12e-3) * 100.0,
        "arm": "measured 12ms vs modeled 10ms"}
    out["drift/modeled_wall"] = {
        "metric": "modeled_wall_ms",
        "modeled_wall_ms": modeled_wall_step_s(8e-3, 4e-3) * 1e3,
        "arm": "overlap 8ms + fwd 2ms"}
    return out


def collect_parallelism() -> dict:
    """Parallelism suite (DESIGN.md §14): fully deterministic — the
    TP×PP×DP×EP placement search on the acceptance (arch, topology)
    points of ``benchmarks/bench_parallelism.py`` (the ``must_win``
    rows).  Gated per point: the best budget-eligible arm of each
    family (DP-only, PP-only, tp/ep) and the budgeted auto pick.  A
    drift in ``model_best`` or ``auto_budget`` means the model-axis
    pricing moved; the DP/PP rows pin the baselines it must keep
    beating."""
    from benchmarks.bench_parallelism import (OPT, POINTS, best_by_family,
                                              build_point)
    from repro.core.schedule import plan_rounds

    out: dict = {}
    for arch, spec, must_win in POINTS:
        if not must_win:
            continue
        profiles, topo, axes = build_point(arch, spec)
        _, arms = plan_rounds(profiles, topo, topo.world, opt_name=OPT,
                              **axes)
        budget = arms["every_step"].opt_mem_bytes * 0.5
        dp, pp, model = best_by_family(arms, budget)
        tight, _ = plan_rounds(profiles, topo, topo.world, opt_name=OPT,
                               memory_budget_bytes=budget, **axes)
        key = f"{arch}/{topo.spec()}"
        for tag, a in (("dp_best", dp), ("pp_best", pp),
                       ("model_best", model), ("auto_budget", tight)):
            out[f"{key}/{tag}"] = {
                "modeled_step_ms": a.modeled_step_s * 1e3, "arm": a.key}
    return out


def collect_elastic() -> dict:
    """Elastic suite (DESIGN.md §15): fully deterministic — a canned
    fault trace replayed host-side (no model, no wall clock) plus the
    planner on the surviving fabric.  Gated numbers: the trace's recovery
    shape (reshard count, steps spent degraded, kill→restore recovery
    interval), the modeled step cost of the post-reshard auto plan on the
    surviving 6-world topology, and the straggler-priced search — whose
    gated cost pins the cadence-demotion math (``straggler_penalty_s``
    charges every-step the full skew per step but a τ-round local-SGD arm
    only skew/τ, so a persistent straggler flips the winner)."""
    from repro.core.schedule import Topology, plan_rounds
    from repro.elastic import FaultSchedule, replay_world_sizes
    from repro.elastic.reshard import surviving_topology

    out: dict = {}
    topo = Topology.from_spec("node:2@datacenter,device:4@fast_ici")
    trace = "kill:3@3,kill:7@3,restore:3@6,restore:7@6"
    sched = FaultSchedule.from_spec(trace, world=topo.world)
    steps = 10
    sizes, changes = replay_world_sizes(sched, steps)
    out["trace/reshards"] = {
        "metric": "n_reshards", "n_reshards": len(changes),
        "arm": f"at steps {changes}"}
    out["trace/degraded_steps"] = {
        "metric": "degraded_steps",
        "degraded_steps": sum(1 for s in sizes if s < topo.world),
        "arm": f"min world {min(sizes)}"}
    out["trace/recovery_steps"] = {
        "metric": "recovery_steps",
        "recovery_steps": changes[1] - changes[0],
        "arm": f"kill@{changes[0]} restore@{changes[1]}"}

    arch = "xlstm-125m"
    _, profiles = _profiles()[arch]
    surviving = surviving_topology(topo, {3, 7})
    best, arms = plan_rounds(profiles, surviving, surviving.world,
                             opt_name=OPT)
    out[f"{arch}/{surviving.spec()}/auto"] = {
        "modeled_step_ms": best.modeled_step_s * 1e3, "arm": best.key}
    out[f"{arch}/{surviving.spec()}/every_step"] = {
        "modeled_step_ms": arms["every_step"].modeled_step_s * 1e3,
        "arm": "every_step"}
    # a straggler skewing 4 every-step comm rounds: the priced search
    # must demote the cadence away from every-step
    skew = arms["every_step"].modeled_step_s * 4.0
    sbest, _ = plan_rounds(profiles, surviving, surviving.world,
                           opt_name=OPT, straggler_s=skew)
    out[f"{arch}/{surviving.spec()}/straggler_auto"] = {
        "modeled_step_ms": sbest.modeled_step_s * 1e3, "arm": sbest.key}

    # the visible cadence demotion: a compute-bound point (4× backward)
    # on the flat fast fabric where every-step wins skew-free, and a 2×
    # skew flips the winner to a τ-round arm — the straggler pays per
    # ROUND, so stretching the cadence amortizes it (survey §3.1.2)
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.schedule import LINK_PRESETS, profiles_from_grads
    from repro.models import Model
    params = Model(get_config(arch)).abstract_params()
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    heavy = profiles_from_grads(params, 4.0 * 4.0 * n * TOKENS / PEAK_FLOPS)
    flat6 = Topology.flat(6, LINK_PRESETS["fast_ici"],
                          link_name="fast_ici")
    calm, carms = plan_rounds(heavy, flat6, 6, opt_name=OPT)
    skew6 = carms["every_step"].modeled_step_s * 2.0
    demoted, _ = plan_rounds(heavy, flat6, 6, opt_name=OPT,
                             straggler_s=skew6)
    out[f"{arch}/flat6_heavy/auto"] = {
        "modeled_step_ms": calm.modeled_step_s * 1e3, "arm": calm.key}
    out[f"{arch}/flat6_heavy/straggler_auto"] = {
        "modeled_step_ms": demoted.modeled_step_s * 1e3,
        "arm": demoted.key}
    if demoted.schedule.kind == calm.schedule.kind:
        raise RuntimeError(
            f"straggler pricing lost the cadence demotion: calm winner "
            f"{calm.key} vs skewed winner {demoted.key}")
    return out


def collect() -> dict:
    """All tracked records, keyed by suite name."""
    from repro.core.schedule import (LINK_PRESETS, PipelineAxis, Topology,
                                     fixed_config_plan,
                                     opt_state_bytes_per_worker, plan,
                                     plan_rounds)
    from repro.core.schedule.planner import (FIXED_BASELINES,
                                             FLAT_RING_CANDIDATES)

    profs = _profiles()
    planner: dict = {}
    sharded: dict = {}
    pipeline: dict = {}
    topology: dict = {}
    for arch, (cfg, profiles) in profs.items():
        pb = float(sum(p.grad_bytes for p in profiles))
        pa = PipelineAxis(global_tokens=float(TOKENS * WORLD),
                          bytes_per_token=float(cfg.d_model * 4))
        for regime in REGIMES:
            link = LINK_PRESETS[regime]
            key = f"{arch}/{regime}"

            # -- planner: overlap-planned auto vs the fixed baselines
            auto = plan(profiles, link, WORLD)
            planner[f"{key}/auto"] = {
                "modeled_step_ms": auto.modeled_step_s * 1e3,
                "arm": f"{auto.n_buckets} buckets"}
            for name, (comp, algo, cargs) in FIXED_BASELINES.items():
                fp = fixed_config_plan(profiles, link, WORLD, comp, algo,
                                       compressor_args=cargs)
                planner[f"{key}/fixed_{name}"] = {
                    "modeled_step_ms": fp.modeled_step_s * 1e3, "arm": name}

            # -- sharded: fixed modes + the budget flip
            for shard in (False, True):
                fp = fixed_config_plan(profiles, link, WORLD, "none",
                                       "ring", shard_state=shard)
                tag = "fixed_sharded" if shard else "fixed_replicated"
                sharded[f"{key}/{tag}"] = {
                    "modeled_step_ms": fp.modeled_step_s * 1e3, "arm": tag}
            budget = opt_state_bytes_per_worker(OPT, pb, WORLD, False) / 2
            tight, _ = plan_rounds(profiles, link, WORLD, opt_name=OPT,
                                   memory_budget_bytes=budget)
            sharded[f"{key}/auto_budget"] = {
                "modeled_step_ms": tight.modeled_step_s * 1e3,
                "arm": tight.key}

            # -- pipeline: fixed DP arms vs pipeline arms (free + budget)
            best, arms = plan_rounds(profiles, link, WORLD, opt_name=OPT,
                                     pipeline=pa)
            for k in ("every_step", "every_step_sharded"):
                pipeline[f"{key}/{k}"] = {
                    "modeled_step_ms": arms[k].modeled_step_s * 1e3,
                    "arm": k}
            pipes = [a for a in arms.values() if a.pipeline_stages > 1]
            pbest = min(pipes, key=lambda a: a.modeled_step_s)
            pipeline[f"{key}/pipeline_best"] = {
                "modeled_step_ms": pbest.modeled_step_s * 1e3,
                "arm": pbest.key}
            pipeline[f"{key}/auto"] = {
                "modeled_step_ms": best.modeled_step_s * 1e3,
                "arm": best.key}
            pbudget = arms["every_step"].opt_mem_bytes * 0.5
            ptight, _ = plan_rounds(profiles, link, WORLD, opt_name=OPT,
                                    pipeline=pa,
                                    memory_budget_bytes=pbudget)
            pipeline[f"{key}/auto_budget"] = {
                "modeled_step_ms": ptight.modeled_step_s * 1e3,
                "arm": ptight.key}

        # -- topology: tiered networks — flat-ring bound, hierarchical
        # fixed plan, and the tier-aware auto pick (rounds axis pinned to
        # every-step so the tracked numbers isolate the network axis)
        for preset in TOPOLOGIES:
            topo = Topology.from_spec(preset)
            tw = topo.world
            tkey = f"{arch}/{preset}"
            tpa = PipelineAxis(global_tokens=float(TOKENS * tw),
                               bytes_per_token=float(cfg.d_model * 4))
            ring_bound = plan(profiles, topo, tw,
                              candidates=FLAT_RING_CANDIDATES)
            topology[f"{tkey}/best_flat_ring"] = {
                "modeled_step_ms": ring_bound.modeled_step_s * 1e3,
                "arm": "ring/psum-restricted"}
            fh = fixed_config_plan(profiles, topo, tw, "none",
                                   "hierarchical")
            topology[f"{tkey}/fixed_hierarchical"] = {
                "modeled_step_ms": fh.modeled_step_s * 1e3,
                "arm": "hierarchical/dense"}
            tbest, tarms = plan_rounds(profiles, topo, tw, opt_name=OPT,
                                       tau_grid=(1,), pipeline=tpa)
            topology[f"{tkey}/every_step"] = {
                "modeled_step_ms": tarms["every_step"].modeled_step_s * 1e3,
                "arm": "+".join(sorted({
                    b.algo for b in tarms["every_step"].comm.buckets}))}
            topology[f"{tkey}/auto"] = {
                "modeled_step_ms": tbest.modeled_step_s * 1e3,
                "arm": tbest.key}
    return {"planner": planner, "sharded": sharded, "pipeline": pipeline,
            "topology": topology, "parallelism": collect_parallelism(),
            "kernels": collect_kernels(), "serving": collect_serving(),
            "calibration": collect_calibration(),
            "elastic": collect_elastic()}


def gate(records: dict, baseline_dir: str, tolerance: float) -> list:
    """Compare against committed baselines; returns failure strings."""
    failures = []
    for suite, recs in records.items():
        path = os.path.join(baseline_dir, f"BENCH_{suite}.json")
        if not os.path.exists(path):
            failures.append(f"{suite}: no baseline at {path} "
                            f"(run --write-baselines and commit)")
            continue
        with open(path) as f:
            base = json.load(f)
        for name, old in base.items():
            if name not in recs:
                failures.append(f"{suite}/{name}: tracked number vanished")
                continue
            metric = old.get("metric", "modeled_step_ms")
            new_v = recs[name].get(metric)
            old_v = old[metric]
            if new_v is None:
                failures.append(f"{suite}/{name}: gated metric "
                                f"{metric!r} vanished")
                continue
            if new_v > old_v * (1.0 + tolerance):
                failures.append(
                    f"{suite}/{name}: {metric} {new_v:.3f} vs baseline "
                    f"{old_v:.3f} (+{(new_v / old_v - 1) * 100:.1f}% "
                    f"> {tolerance * 100:.0f}%)")
        for name in recs:
            if name not in base:
                print(f"note: {suite}/{name} is new (not in baseline); "
                      f"refresh baselines to track it")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir",
                    default=os.path.join(REPO, "benchmarks", "baselines"))
    ap.add_argument("--out-dir",
                    default=os.path.join(REPO, "artifacts", "bench"),
                    help="where BENCH_<suite>.json land (CI uploads them)")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="relative regression that fails the gate")
    ap.add_argument("--perturb", type=float, default=0.0,
                    help="inflate every number by this fraction before the "
                         "comparison (negative test: the gate must trip)")
    ap.add_argument("--write-baselines", action="store_true",
                    help="write the computed records AS the baselines")
    args = ap.parse_args(argv)

    records = collect()
    if args.perturb:
        for recs in records.values():
            for r in recs.values():
                r[r.get("metric", "modeled_step_ms")] *= (1.0 + args.perturb)

    os.makedirs(args.out_dir, exist_ok=True)
    for suite, recs in records.items():
        out = os.path.join(args.out_dir, f"BENCH_{suite}.json")
        with open(out, "w") as f:
            json.dump(recs, f, indent=1, sort_keys=True)
        print(f"wrote {out} ({len(recs)} tracked numbers)")

    if args.write_baselines:
        os.makedirs(args.baseline_dir, exist_ok=True)
        for suite, recs in records.items():
            path = os.path.join(args.baseline_dir, f"BENCH_{suite}.json")
            with open(path, "w") as f:
                json.dump(recs, f, indent=1, sort_keys=True)
            print(f"baseline written: {path}")
        return 0

    failures = gate(records, args.baseline_dir, args.tolerance)
    if failures:
        print("BENCH REGRESSION GATE FAILED:")
        for f in failures:
            print("  " + f)
        return 1
    n = sum(len(r) for r in records.values())
    print(f"bench gate OK: {n} tracked numbers within "
          f"{args.tolerance * 100:.0f}% of baselines")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
