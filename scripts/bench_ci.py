"""CI benchmark-regression harness (ISSUE 4 satellite).

Runs the MODELED planner benches — planner / sharded / pipeline — fully
deterministically (abstract params + the α-β cost model; no wall-clock
timing, so the numbers are bit-stable across machines), writes one
``BENCH_<suite>.json`` per suite, and fails CI when any tracked number
regresses more than ``--tolerance`` (default 10%) against the committed
baselines in ``benchmarks/baselines/``.

    PYTHONPATH=src python scripts/bench_ci.py                 # gate
    PYTHONPATH=src python scripts/bench_ci.py --write-baselines
    PYTHONPATH=src python scripts/bench_ci.py --perturb 0.2   # negative test

The ``--perturb`` flag multiplies every computed number by (1 + p) before
the comparison — the injected-regression negative test the CI workflow
runs to prove the gate actually trips.

Record schema (per suite file)::

    {"<arch>/<link>/<point>": {"modeled_step_ms": 12.345, "arm": "..."},
     ...}

Tracked points are the acceptance quantities of each execution mode: the
auto plan and the fixed baselines it must beat (planner), the
replicated/sharded fixed modes and the budget flip (sharded), the fixed DP
arms vs the best pipeline arm and the budget pick (pipeline), and — on the
tiered networks (ISSUE 5) — the flat-ring bound vs the hierarchical fixed
plan vs the tier-aware auto pick per topology (topology).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

ARCHS = ("xlstm-125m", "gemma-2b", "chameleon-34b")
REGIMES = ("fast_ici", "commodity")
# tiered networks tracked by the topology suite (TOPOLOGY_PRESETS names)
TOPOLOGIES = ("two_tier_pod", "commodity_cluster")
PEAK_FLOPS = 197e12
TOKENS = 4096
WORLD = 256
OPT = "adam"


def _profiles():
    import jax
    import numpy as np

    from repro.core.schedule import profiles_from_grads
    from repro.configs import get_config
    from repro.models import Model
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch)
        params = Model(cfg).abstract_params()
        n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        t_backward = 4.0 * n * TOKENS / PEAK_FLOPS
        out[arch] = (cfg, profiles_from_grads(params, t_backward))
    return out


def collect() -> dict:
    """All tracked records, keyed by suite name."""
    from repro.core.schedule import (LINK_PRESETS, PipelineAxis, Topology,
                                     fixed_config_plan,
                                     opt_state_bytes_per_worker, plan,
                                     plan_rounds)
    from repro.core.schedule.planner import (FIXED_BASELINES,
                                             FLAT_RING_CANDIDATES)

    profs = _profiles()
    planner: dict = {}
    sharded: dict = {}
    pipeline: dict = {}
    topology: dict = {}
    for arch, (cfg, profiles) in profs.items():
        pb = float(sum(p.grad_bytes for p in profiles))
        pa = PipelineAxis(global_tokens=float(TOKENS * WORLD),
                          bytes_per_token=float(cfg.d_model * 4))
        for regime in REGIMES:
            link = LINK_PRESETS[regime]
            key = f"{arch}/{regime}"

            # -- planner: overlap-planned auto vs the fixed baselines
            auto = plan(profiles, link, WORLD)
            planner[f"{key}/auto"] = {
                "modeled_step_ms": auto.modeled_step_s * 1e3,
                "arm": f"{auto.n_buckets} buckets"}
            for name, (comp, algo, cargs) in FIXED_BASELINES.items():
                fp = fixed_config_plan(profiles, link, WORLD, comp, algo,
                                       compressor_args=cargs)
                planner[f"{key}/fixed_{name}"] = {
                    "modeled_step_ms": fp.modeled_step_s * 1e3, "arm": name}

            # -- sharded: fixed modes + the budget flip
            for shard in (False, True):
                fp = fixed_config_plan(profiles, link, WORLD, "none",
                                       "ring", shard_state=shard)
                tag = "fixed_sharded" if shard else "fixed_replicated"
                sharded[f"{key}/{tag}"] = {
                    "modeled_step_ms": fp.modeled_step_s * 1e3, "arm": tag}
            budget = opt_state_bytes_per_worker(OPT, pb, WORLD, False) / 2
            tight, _ = plan_rounds(profiles, link, WORLD, opt_name=OPT,
                                   memory_budget_bytes=budget)
            sharded[f"{key}/auto_budget"] = {
                "modeled_step_ms": tight.modeled_step_s * 1e3,
                "arm": tight.key}

            # -- pipeline: fixed DP arms vs pipeline arms (free + budget)
            best, arms = plan_rounds(profiles, link, WORLD, opt_name=OPT,
                                     pipeline=pa)
            for k in ("every_step", "every_step_sharded"):
                pipeline[f"{key}/{k}"] = {
                    "modeled_step_ms": arms[k].modeled_step_s * 1e3,
                    "arm": k}
            pipes = [a for a in arms.values() if a.pipeline_stages > 1]
            pbest = min(pipes, key=lambda a: a.modeled_step_s)
            pipeline[f"{key}/pipeline_best"] = {
                "modeled_step_ms": pbest.modeled_step_s * 1e3,
                "arm": pbest.key}
            pipeline[f"{key}/auto"] = {
                "modeled_step_ms": best.modeled_step_s * 1e3,
                "arm": best.key}
            pbudget = arms["every_step"].opt_mem_bytes * 0.5
            ptight, _ = plan_rounds(profiles, link, WORLD, opt_name=OPT,
                                    pipeline=pa,
                                    memory_budget_bytes=pbudget)
            pipeline[f"{key}/auto_budget"] = {
                "modeled_step_ms": ptight.modeled_step_s * 1e3,
                "arm": ptight.key}

        # -- topology: tiered networks — flat-ring bound, hierarchical
        # fixed plan, and the tier-aware auto pick (rounds axis pinned to
        # every-step so the tracked numbers isolate the network axis)
        for preset in TOPOLOGIES:
            topo = Topology.from_spec(preset)
            tw = topo.world
            tkey = f"{arch}/{preset}"
            tpa = PipelineAxis(global_tokens=float(TOKENS * tw),
                               bytes_per_token=float(cfg.d_model * 4))
            ring_bound = plan(profiles, topo, tw,
                              candidates=FLAT_RING_CANDIDATES)
            topology[f"{tkey}/best_flat_ring"] = {
                "modeled_step_ms": ring_bound.modeled_step_s * 1e3,
                "arm": "ring/psum-restricted"}
            fh = fixed_config_plan(profiles, topo, tw, "none",
                                   "hierarchical")
            topology[f"{tkey}/fixed_hierarchical"] = {
                "modeled_step_ms": fh.modeled_step_s * 1e3,
                "arm": "hierarchical/dense"}
            tbest, tarms = plan_rounds(profiles, topo, tw, opt_name=OPT,
                                       tau_grid=(1,), pipeline=tpa)
            topology[f"{tkey}/every_step"] = {
                "modeled_step_ms": tarms["every_step"].modeled_step_s * 1e3,
                "arm": "+".join(sorted({
                    b.algo for b in tarms["every_step"].comm.buckets}))}
            topology[f"{tkey}/auto"] = {
                "modeled_step_ms": tbest.modeled_step_s * 1e3,
                "arm": tbest.key}
    return {"planner": planner, "sharded": sharded, "pipeline": pipeline,
            "topology": topology}


def gate(records: dict, baseline_dir: str, tolerance: float) -> list:
    """Compare against committed baselines; returns failure strings."""
    failures = []
    for suite, recs in records.items():
        path = os.path.join(baseline_dir, f"BENCH_{suite}.json")
        if not os.path.exists(path):
            failures.append(f"{suite}: no baseline at {path} "
                            f"(run --write-baselines and commit)")
            continue
        with open(path) as f:
            base = json.load(f)
        for name, old in base.items():
            if name not in recs:
                failures.append(f"{suite}/{name}: tracked number vanished")
                continue
            new_ms = recs[name]["modeled_step_ms"]
            old_ms = old["modeled_step_ms"]
            if new_ms > old_ms * (1.0 + tolerance):
                failures.append(
                    f"{suite}/{name}: {new_ms:.3f} ms vs baseline "
                    f"{old_ms:.3f} ms (+{(new_ms / old_ms - 1) * 100:.1f}% "
                    f"> {tolerance * 100:.0f}%)")
        for name in recs:
            if name not in base:
                print(f"note: {suite}/{name} is new (not in baseline); "
                      f"refresh baselines to track it")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir",
                    default=os.path.join(REPO, "benchmarks", "baselines"))
    ap.add_argument("--out-dir",
                    default=os.path.join(REPO, "artifacts", "bench"),
                    help="where BENCH_<suite>.json land (CI uploads them)")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="relative regression that fails the gate")
    ap.add_argument("--perturb", type=float, default=0.0,
                    help="inflate every number by this fraction before the "
                         "comparison (negative test: the gate must trip)")
    ap.add_argument("--write-baselines", action="store_true",
                    help="write the computed records AS the baselines")
    args = ap.parse_args(argv)

    records = collect()
    if args.perturb:
        for recs in records.values():
            for r in recs.values():
                r["modeled_step_ms"] *= (1.0 + args.perturb)

    os.makedirs(args.out_dir, exist_ok=True)
    for suite, recs in records.items():
        out = os.path.join(args.out_dir, f"BENCH_{suite}.json")
        with open(out, "w") as f:
            json.dump(recs, f, indent=1, sort_keys=True)
        print(f"wrote {out} ({len(recs)} tracked numbers)")

    if args.write_baselines:
        os.makedirs(args.baseline_dir, exist_ok=True)
        for suite, recs in records.items():
            path = os.path.join(args.baseline_dir, f"BENCH_{suite}.json")
            with open(path, "w") as f:
                json.dump(recs, f, indent=1, sort_keys=True)
            print(f"baseline written: {path}")
        return 0

    failures = gate(records, args.baseline_dir, args.tolerance)
    if failures:
        print("BENCH REGRESSION GATE FAILED:")
        for f in failures:
            print("  " + f)
        return 1
    n = sum(len(r) for r in records.values())
    print(f"bench gate OK: {n} tracked numbers within "
          f"{args.tolerance * 100:.0f}% of baselines")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
