#!/usr/bin/env bash
# Smoke suite: tier-1 tests (fast selection — pytest.ini excludes the
# `slow` marker, which runs as its own CI matrix job) + quickstart example
# + a 5-step `--sync auto` train + a 3-step `--shard-state` train on the
# reduced xlstm-125m config.  Run from the repo root:
#
#     bash scripts/ci.sh [--fast]
#
# --fast skips the (slow on CPU) xlstm trains.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1: pytest (fast selection) ==="
python -m pytest -x -q

echo "=== smoke: examples/quickstart.py ==="
python examples/quickstart.py

if [[ "${1:-}" != "--fast" ]]; then
  echo "=== smoke: 5-step --sync auto train (reduced xlstm-125m) ==="
  # --plan-backward-ms models a TPU backward so the rounds axis is live on
  # CPU (the measured CPU backward would dwarf modeled comm and pin the
  # planner to every_step); expected pick: local_sgd τ + compressed rounds.
  python -m repro.launch.train --arch xlstm-125m --reduced \
      --steps 5 --batch 2 --seq 32 --sync auto \
      --plan-world 256 --link commodity --plan-backward-ms 20 --log-every 1

  echo "=== smoke: 3-step sharded-DP train (--shard-state) ==="
  python -m repro.launch.train --arch xlstm-125m --reduced \
      --steps 3 --batch 2 --seq 32 --shard-state --log-every 1
fi

echo "=== smoke: planner + sharded benchmarks (modeled tables) ==="
python -m benchmarks.run --only planner
python -m benchmarks.run --only sharded

echo "ALL SMOKE CHECKS PASSED"
