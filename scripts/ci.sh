#!/usr/bin/env bash
# Smoke suite: tier-1 tests (fast selection — pytest.ini excludes the
# `slow` marker, which runs as its own CI matrix job) + quickstart example
# + a 5-step `--sync auto` train + a 3-step sharded train + a 3-step
# `--parallelism dp=2,tp=2` MoE train + a 3-step micro-batched pipeline
# train on reduced configs.  Run from the repo root:
#
#     bash scripts/ci.sh [--fast]
#
# --fast skips the (slow on CPU) e2e trains.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Name every step and echo the one that died: when a python process is
# killed (OOM, timeout) the log otherwise ends mid-stream with no hint of
# which check was running.
CURRENT_STEP="startup"
step() { CURRENT_STEP="$1"; echo "=== $1 ==="; }
trap 'code=$?; if [[ $code -ne 0 ]]; then
        echo "ci.sh: FAILED during: ${CURRENT_STEP} (exit ${code})" >&2
      fi' EXIT

# Device-count detection: multi-device-only smokes (pipeline S>=2, the
# measured sharded comparison at world>1) self-gate on what exists here
# instead of assuming a fixed mesh.
DEVICES=$(python -c "import jax; print(len(jax.devices()))")
echo "detected ${DEVICES} jax device(s)"

step "tier-1: pytest (fast selection)"
python -m pytest -x -q

step "smoke: examples/quickstart.py"
python examples/quickstart.py

if [[ "${1:-}" != "--fast" ]]; then
  step "smoke: 5-step --sync auto train (reduced xlstm-125m)"
  # --plan-backward-ms models a TPU backward so the rounds axis is live on
  # CPU (the measured CPU backward would dwarf modeled comm and pin the
  # planner to every_step); expected pick: local_sgd τ + compressed rounds.
  # the commodity_cluster preset (world 256) replaces the removed
  # --plan-world 256 flag: the topology's tier product IS the world
  python -m repro.launch.train --arch xlstm-125m --reduced \
      --steps 5 --batch 2 --seq 32 --sync auto \
      --topology commodity_cluster --plan-backward-ms 20 --log-every 1

  step "smoke: 3-step sharded-DP train (--parallelism shard)"
  python -m repro.launch.train --arch xlstm-125m --reduced \
      --steps 3 --batch 2 --seq 32 --parallelism shard --log-every 1

  step "smoke: 3-step --parallelism dp=2,tp=2 --sync auto (reduced qwen3-moe)"
  # the unified spec end to end on an MoE stack (DESIGN.md §14): the
  # planner prices tp/ep/pp arms on the world-4 topology, the pinned
  # dp=2,tp=2 spec filters the pool, and the plan record must carry the
  # additive parallelism block (absent from pure-dp records — PR 8 rule)
  python -m repro.launch.train --arch qwen3-moe-30b-a3b --reduced \
      --steps 3 --batch 2 --seq 32 --sync auto \
      --parallelism dp=2,tp=2 \
      --topology node:2@datacenter,device:2@fast_ici \
      --plan-backward-ms 20 --log-every 1
  python - <<'PY'
import json
with open("artifacts/comm_plans/qwen3-moe-30b-a3b.json") as f:
    rec = json.load(f)
par = rec.get("parallelism")
assert par, f"plan record missing the parallelism block: {sorted(rec)}"
assert (par["dp"], par["tp"]) == (2, 2), f"wrong parallelism block: {par}"
assert par["spec"].startswith("dp=2,tp=2"), par
print(f"parallelism block OK: {par}")
PY

  step "smoke: 3-step fused-wire train (int8_fused/ring, DESIGN.md §11)"
  # the fused one-pass compressed wire in a REAL training loop: EF +
  # quantize + pack in one kernel dispatch, fused dequant+accum decode
  python -m repro.launch.train --arch xlstm-125m --reduced \
      --steps 3 --batch 2 --seq 32 --sync comm \
      --compressor int8_fused --algo ring --log-every 1

  step "smoke: elastic kill-at-step-k train (8→6→8, DESIGN.md §15)"
  # the fault-tolerant runtime end to end: kill one device per node at
  # step 3, reshard 8→6 through the portable checkpoint WITHOUT a
  # process restart, restore the fleet at step 6 — and the resumed loss
  # trajectory must reproduce the unfaulted run bit for bit (on a
  # 1-device host the world is a planning model, so the executed math is
  # world-independent; any difference is a restore bug)
  python - <<'PY'
import numpy as np
from repro.launch.train import main
faulted = main(["--arch", "xlstm-125m", "--reduced", "--steps", "8",
                "--batch", "2", "--seq", "32", "--elastic",
                "--topology", "node:2@datacenter,device:4@fast_ici",
                "--fault-trace",
                "kill:3@3,kill:7@3,restore:3@6,restore:7@6",
                "--log-every", "0"])
plain = main(["--arch", "xlstm-125m", "--reduced", "--steps", "8",
              "--batch", "2", "--seq", "32", "--log-every", "0"])
np.testing.assert_array_equal(
    np.asarray(faulted), np.asarray(plain),
    err_msg="elastic resume diverged from the unfaulted trajectory")
print("elastic kill-at-step-3 smoke OK: 8 losses bit-identical")
PY

  step "smoke: 3-step two-tier --topology --sync auto train"
  # the tiered network model (DESIGN.md §10): the planner prices every
  # phase per tier and must pick a tier-aware arm (hierarchical buckets
  # or a placed pipeline); on a 1-device host the topology is a planning
  # model and the winner executes on the flat mesh
  python -m repro.launch.train --arch xlstm-125m --reduced \
      --steps 3 --batch 2 --seq 32 --sync auto \
      --topology node:4@datacenter,device:8@fast_ici \
      --plan-backward-ms 20 --log-every 1

  step "smoke: 5-step --calibrate --sync auto train (drift record)"
  # the modeled<->measured loop (DESIGN.md §13): time real collectives on
  # this host, fit alpha/beta with confidence bounds, plan on the fitted
  # fabric, and close the loop — the plan record must carry the fitted
  # calibration block and a POPULATED drift block
  python -m repro.launch.train --arch xlstm-125m --reduced \
      --steps 5 --batch 2 --seq 32 --sync auto --calibrate --log-every 1
  python - <<'PY'
import json
with open("artifacts/comm_plans/xlstm-125m.json") as f:
    rec = json.load(f)
assert "calibration" in rec, "plan record missing the calibration block"
tiers = rec["calibration"]["tiers"]
assert tiers and all("alpha_s" in t and "alpha_err_s" in t for t in tiers), \
    f"calibration tiers lack fitted alpha/beta + bounds: {tiers}"
d = rec.get("drift")
assert d, "plan record missing the drift block"
for k in ("modeled_wall_step_s", "measured_step_s", "drift_pct",
          "fit_error_s", "within_fit_error", "arms"):
    assert k in d, f"drift block missing {k!r}: {sorted(d)}"
assert d["measured_step_s"] > 0 and d["arms"], "drift block not populated"
print(f"drift block OK: modeled {d['modeled_wall_step_s']*1e3:.1f} ms vs "
      f"measured {d['measured_step_s']*1e3:.1f} ms "
      f"({d['drift_pct']:+.1f}%, within_fit_error={d['within_fit_error']})")
PY

  if (( DEVICES % 2 == 0 && DEVICES >= 2 )); then
    step "smoke: 3-step pipeline train (pp=2, micro=2, reduced gemma-2b)"
    python -m repro.launch.train --arch gemma-2b --reduced \
        --steps 3 --batch $(( 2 * DEVICES )) --seq 32 \
        --parallelism pp=2,micro=2 --log-every 1
  else
    step "smoke: 3-step micro-batched pipeline path (S=1, micro=2)"
    # one device: the 1F1B executor still runs (degenerate pipe), covering
    # micro-batching, the per-row DP edge and the stage reports; S>=2 is
    # exercised by the multi-device CI job (tests/multi_device_checks.py)
    python -m repro.launch.train --arch gemma-2b --reduced \
        --steps 3 --batch 2 --seq 32 \
        --parallelism micro=2 --log-every 1
  fi
fi

if [[ "${1:-}" != "--fast" ]]; then
  step "smoke: 2-replica continuous serving (paged KV, DESIGN.md §12)"
  # the serving engine end to end: paged KV cache, continuous batching
  # with mid-stream admission, least-loaded routing across 2 replicas
  python -m repro.launch.serve --arch gemma-2b --batch 2 \
      --prompt-len 8 --gen 4 --requests 6 --replicas 2 --engine continuous
fi

step "smoke: planner + sharded + pipeline + serving benchmarks"
python -m benchmarks.run --only planner
python -m benchmarks.run --only sharded
python -m benchmarks.run --only pipeline
python -m benchmarks.run --only serving

step "smoke: bench regression gate (scripts/bench_ci.py)"
python scripts/bench_ci.py --out-dir artifacts/bench

echo "ALL SMOKE CHECKS PASSED"
