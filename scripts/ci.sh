#!/usr/bin/env bash
# Smoke suite: tier-1 tests + quickstart example + a 5-step `--sync auto`
# train on the reduced xlstm-125m config (the communication-planner
# acceptance path).  Run from the repo root:
#
#     bash scripts/ci.sh [--fast]
#
# --fast skips the (slow on CPU) xlstm auto-train.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1: pytest ==="
python -m pytest -x -q

echo "=== smoke: examples/quickstart.py ==="
python examples/quickstart.py

if [[ "${1:-}" != "--fast" ]]; then
  echo "=== smoke: 5-step --sync auto train (reduced xlstm-125m) ==="
  # --plan-backward-ms models a TPU backward so the rounds axis is live on
  # CPU (the measured CPU backward would dwarf modeled comm and pin the
  # planner to every_step); expected pick: local_sgd τ + compressed rounds.
  python -m repro.launch.train --arch xlstm-125m --reduced \
      --steps 5 --batch 2 --seq 32 --sync auto \
      --plan-world 256 --link commodity --plan-backward-ms 20 --log-every 1
fi

echo "=== smoke: planner benchmark (modeled only is fast; full table) ==="
python -m benchmarks.run --only planner

echo "ALL SMOKE CHECKS PASSED"
